# Per-architecture smoke tests on REDUCED configs (assignment requirement):
# forward/train step on CPU asserting output shapes + no NaNs, decode
# consistency with prefill, and a gradient step that changes the loss.
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_archs, reduced_config, valid_cells
from repro.models.transformer import Model, prefill_forward

ARCHS = list_archs()


def make_batch(cfg, B, S, key):
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(key, (B, S, cfg.d_model), jnp.float32),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        }
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_forward_and_loss(arch):
    cfg = reduced_config(get_config(arch))
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    B, S = 2, 32
    batch = make_batch(cfg, B, S, key)
    logits, aux = m.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    loss, metrics = m.loss(params, batch)
    assert np.isfinite(float(loss))
    if cfg.moe is not None:
        assert np.isfinite(float(metrics["lb_loss"]))


@pytest.mark.parametrize("arch", [a for a in ARCHS if get_config(a).supports_decode])
def test_arch_decode_no_nan(arch):
    cfg = reduced_config(get_config(arch))
    m = Model(cfg)
    key = jax.random.PRNGKey(1)
    params = m.init_params(key)
    B = 2
    cache = m.cache_init(B, 64)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, cache2 = m.decode_step(params, cache, {"tokens": tok, "pos": jnp.asarray(0)})
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["gemma2-9b", "rwkv6-3b", "zamba2-7b", "dbrx-132b"])
def test_decode_matches_forward(arch):
    """Golden consistency: teacher-forced decode logits == forward logits.
    MoE needs ample capacity: train-time capacity drops are batch-dependent
    and legitimately differ from single-token decode."""
    cfg = reduced_config(get_config(arch))
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = Model(cfg)
    key = jax.random.PRNGKey(2)
    params = m.init_params(key)
    B, S = 1, 12
    toks = jax.random.randint(key, (B, S), 4, cfg.vocab_size)
    ref_logits, _ = m.forward(params, {"tokens": toks})
    cache = m.cache_init(B, S)
    outs = []
    for t in range(S):
        lg, cache = m.decode_step(params, cache, {"tokens": toks[:, t : t + 1], "pos": jnp.asarray(t)})
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(ref_logits, np.float32), rtol=0.15, atol=0.15
    )


@pytest.mark.parametrize("arch", ["gemma3-4b", "starcoder2-3b"])
def test_prefill_matches_forward_tail(arch):
    cfg = reduced_config(get_config(arch))
    m = Model(cfg)
    key = jax.random.PRNGKey(3)
    params = m.init_params(key)
    toks = jax.random.randint(key, (2, 24), 4, cfg.vocab_size)
    full, _ = m.forward(params, {"tokens": toks})
    last, cache = prefill_forward(params, {"tokens": toks}, cfg)
    np.testing.assert_allclose(
        np.asarray(last[:, 0], np.float32), np.asarray(full[:, -1], np.float32), rtol=5e-2, atol=5e-2
    )
    # prefill -> decode continuation consistency
    nxt = jnp.argmax(last[:, 0], axis=-1)[:, None].astype(jnp.int32)
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    full2, _ = m.forward(params, {"tokens": toks2})
    # decode caches from prefill have length 24; decode pos=24 needs slot: pad
    cache_full = m.cache_init(2, 25)
    cache_pad = jax.tree.map(
        lambda a, b: jnp.pad(a, [(0, bs - as_) for as_, bs in zip(a.shape, b.shape)]),
        cache, cache_full)
    lg, _ = m.decode_step(params, cache_pad, {"tokens": nxt, "pos": jnp.asarray(24)})
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32), np.asarray(full2[:, -1], np.float32), rtol=0.15, atol=0.15
    )


def test_train_step_reduces_loss():
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.step import TrainSpec, make_train_step

    cfg = dataclasses.replace(reduced_config(get_config("starcoder2-3b")), n_layers=2, vocab_size=64)
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(m, AdamWConfig(lr_peak=1e-2, warmup_steps=2, total_steps=50),
                                   TrainSpec(microbatches=1, remat=False)))
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, 64)}
    losses = []
    for _ in range(8):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_microbatch_equivalence():
    """grad accumulation over 4 microbatches ≈ single-batch gradients."""
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.step import TrainSpec, make_train_step

    cfg = dataclasses.replace(reduced_config(get_config("starcoder2-3b")), n_layers=2, vocab_size=64)
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    batch = {"tokens": jax.random.randint(key, (8, 16), 0, 64)}
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=0, total_steps=10)
    s1 = make_train_step(m, opt_cfg, TrainSpec(microbatches=1, remat=False))
    s4 = make_train_step(m, opt_cfg, TrainSpec(microbatches=4, remat=False))
    p1, _, m1 = s1(params, adamw_init(params), batch)
    p4, _, m4 = s4(params, adamw_init(params), batch)
    # parameters after one step agree to accumulation tolerance
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p4)
    assert max(jax.tree.leaves(d)) < 5e-2


def test_remat_matches_no_remat():
    cfg = dataclasses.replace(reduced_config(get_config("gemma2-9b")), vocab_size=64)
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, 64)}
    l1, _ = m.loss(params, batch, remat=False)
    l2, _ = m.loss(params, batch, remat=True)
    assert abs(float(l1) - float(l2)) < 1e-3


@pytest.mark.parametrize("arch", ARCHS)
def test_valid_cells_assignment_rules(arch):
    cfg = get_config(arch)
    cells = valid_cells(cfg)
    assert "train_4k" in cells and "prefill_32k" in cells
    if not cfg.supports_decode:
        assert "decode_32k" not in cells and "long_500k" not in cells
    if not cfg.subquadratic:
        assert "long_500k" not in cells

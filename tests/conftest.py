# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 host devices.
import os

import numpy as np
import pytest

# every optimize() in the suite runs the IR verifier after each pass unless a
# run explicitly opts out (REPRO_VERIFY_IR=0)
os.environ.setdefault("REPRO_VERIFY_IR", "1")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)

# Per-kernel validation: shape/dtype sweeps, Pallas (interpret mode) vs the
# pure-jnp oracle, the fused multi-aggregate differential matrix, plus
# hypothesis property tests on segreduce (skipped if hypothesis is absent —
# the matrix below must run regardless).
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from repro.kernels.segreduce.kernel import (
    fused_segreduce_pallas,
    op_identity,
    segreduce_pallas,
)
from repro.kernels.segreduce.ref import fused_segreduce_ref, segreduce_ref
from repro.kernels.flash.kernel import flash_attention_pallas
from repro.kernels.flash.ref import attention_ref
from repro.kernels.wkv6.kernel import wkv6_pallas
from repro.kernels.wkv6.ref import wkv6_ref

# ---------------------------------------------------------------------------
# segreduce
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [8, 100, 1024, 5000])
@pytest.mark.parametrize("k", [1, 7, 128, 1000])
@pytest.mark.parametrize("op", ["sum", "max", "min"])
def test_segreduce_sweep(rng, n, k, op):
    keys = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    vals = jnp.asarray(rng.normal(size=n), jnp.float32)
    got = segreduce_pallas(keys, vals, k, op=op, interpret=True)
    want = segreduce_ref(keys, vals, k, op=op)
    if op in ("max", "min"):
        # empty segments: kernel and ref both yield the ∓inf identity
        mask = np.asarray(segreduce_ref(keys, jnp.ones_like(vals), k)) > 0
        np.testing.assert_allclose(np.asarray(got)[mask], np.asarray(want)[mask], rtol=1e-6, atol=1e-6)
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("op", ["sum", "max", "min"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_segreduce_dtypes(rng, dtype, op):
    """Input dtype is PRESERVED (int32 in → int32 out), with dtype-correct
    identities — int MIN/MAX use the iinfo extremes, not a float sentinel."""
    keys = jnp.asarray(rng.integers(0, 33, 500), jnp.int32)
    vals = jnp.asarray(rng.integers(0, 10, 500)).astype(dtype)
    got = segreduce_pallas(keys, vals, 33, op=op, interpret=True)
    want = segreduce_ref(keys, vals, 33, op=op)
    assert got.dtype == jnp.dtype(dtype)
    assert want.dtype == jnp.dtype(dtype)
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(want, np.float64), rtol=1e-2, atol=1e-2
    )


def test_segreduce_int_extremes_identity():
    """Empty int32 MIN/MAX segments hold the iinfo identity, and negative
    extremes survive (a -inf/f32 sentinel would corrupt both)."""
    keys = jnp.asarray([0, 0, 2], jnp.int32)
    vals = jnp.asarray([-(2**31) + 5, 7, -3], jnp.int32)
    mx = segreduce_pallas(keys, vals, 3, op="max", interpret=True)
    mn = segreduce_pallas(keys, vals, 3, op="min", interpret=True)
    assert mx.dtype == jnp.int32 and mn.dtype == jnp.int32
    assert np.asarray(mx).tolist() == [7, np.iinfo(np.int32).min, -3]
    assert np.asarray(mn).tolist() == [-(2**31) + 5, np.iinfo(np.int32).max, -3]


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 2000), k=st.integers(1, 300), seed=st.integers(0, 99))
    def test_property_segreduce_equals_ref(n, k, seed):
        rng = np.random.default_rng(seed)
        keys = jnp.asarray(rng.integers(0, k, n), jnp.int32)
        vals = jnp.asarray(rng.normal(size=n), jnp.float32)
        got = segreduce_pallas(keys, vals, k, interpret=True)
        want = segreduce_ref(keys, vals, k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused multi-aggregate segreduce: the differential matrix
#
# Query-level ops {SUM, COUNT, MIN, MAX, AVG} × value dtypes {int32, f32} ×
# {unfiltered, filtered} × {empty table, empty groups, single tile,
# multi-tile}, for BOTH implementations (Pallas interpret mode and the
# pure-jnp fused fallback) against a row-loop numpy oracle; plus
# partial-merge associativity of the multi-accumulator state.
# ---------------------------------------------------------------------------

# (n rows, num_keys, key range) — TILE=1024 ⇒ multi_tile spans 5 row tiles,
# and empty_groups leaves keys [8, 64) with no rows at all
_SHAPES = {
    "empty_table": (0, 16, 16),
    "empty_groups": (200, 64, 8),
    "single_tile": (300, 16, 16),
    "multi_tile": (5000, 16, 16),
}

_MERGE_NP = {"sum": np.add, "max": np.maximum, "min": np.minimum}


def _query_lowering(qop, vals_np):
    """Lower one query-level aggregate to kernel (columns, ops), matching
    the SQL frontend: COUNT is a sum of ones, AVG a SUM/COUNT pair."""
    ones = np.ones(vals_np.shape[0], np.int32)
    if qop == "SUM":
        return [vals_np], ["sum"]
    if qop == "COUNT":
        return [ones], ["sum"]
    if qop == "MIN":
        return [vals_np], ["min"]
    if qop == "MAX":
        return [vals_np], ["max"]
    if qop == "AVG":
        return [vals_np, ones], ["sum", "sum"]
    raise AssertionError(qop)


def _oracle(keys, vals, op, mask, num_keys):
    """Row-loop numpy oracle: per-group reduction with op identities."""
    out = np.full(num_keys, op_identity(op, vals.dtype), vals.dtype)
    for key, val, m in zip(keys, vals, mask):
        if not m:
            continue
        if op == "sum":
            out[key] += val
        elif op == "max":
            out[key] = max(out[key], val)
        else:
            out[key] = min(out[key], val)
    return out


def _matrix_inputs(rng, shape, dtype, filtered):
    n, num_keys, key_range = _SHAPES[shape]
    keys = rng.integers(0, key_range, n).astype(np.int32)
    if dtype == "int32":
        vals = rng.integers(-50, 50, n).astype(np.int32)
    else:
        vals = rng.normal(size=n).astype(np.float32)
    mask = rng.integers(0, 2, n).astype(bool) if filtered else np.ones(n, bool)
    return keys, vals, mask, num_keys


def _run_fused(impl, keys, values, ops, num_keys, mask):
    fn = fused_segreduce_pallas if impl == "pallas" else fused_segreduce_ref
    kwargs = {"interpret": True} if impl == "pallas" else {}
    return fn(
        jnp.asarray(keys),
        tuple(jnp.asarray(v) for v in values),
        tuple(ops),
        num_keys,
        mask=jnp.asarray(mask),
        **kwargs,
    )


@pytest.mark.parametrize("shape", list(_SHAPES))
@pytest.mark.parametrize("filtered", [False, True], ids=["unfiltered", "filtered"])
@pytest.mark.parametrize("dtype", ["int32", "float32"])
@pytest.mark.parametrize("qop", ["SUM", "COUNT", "MIN", "MAX", "AVG"])
@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_fused_differential_matrix(rng, impl, qop, dtype, filtered, shape):
    keys, vals, mask, num_keys = _matrix_inputs(rng, shape, dtype, filtered)
    cols, ops = _query_lowering(qop, vals)
    accs, pres = _run_fused(impl, keys, cols, ops, num_keys, mask)

    pres_np = np.array([np.sum((keys == g) & mask) for g in range(num_keys)])
    np.testing.assert_array_equal(np.asarray(pres), pres_np)
    for col, op, acc in zip(cols, ops, accs):
        want = _oracle(keys, col, op, mask, num_keys)
        got = np.asarray(acc)
        assert got.dtype == col.dtype, (impl, qop, got.dtype, col.dtype)
        np.testing.assert_allclose(
            got.astype(np.float64), want.astype(np.float64), rtol=1e-5, atol=1e-5
        )
    if qop == "AVG":  # the pair the frontend divides: sum / count where count > 0
        s, c = np.asarray(accs[0], np.float64), np.asarray(accs[1], np.float64)
        avg = np.divide(s, c, out=np.zeros_like(s), where=c > 0)
        want_avg = np.zeros(num_keys)
        for g in range(num_keys):
            sel = vals[(keys == g) & mask]
            if len(sel):
                want_avg[g] = sel.astype(np.float64).mean()
        np.testing.assert_allclose(avg, want_avg, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_fused_multi_aggregate_mixed_dtypes(rng, impl):
    """One launch, four aggregates over distinct columns and mixed dtypes —
    the whole-query shape the engine actually emits."""
    n, num_keys = 4000, 48
    keys = rng.integers(0, num_keys, n).astype(np.int32)
    vi = rng.integers(-100, 100, n).astype(np.int32)
    vf = rng.normal(size=n).astype(np.float32)
    mask = rng.integers(0, 4, n) > 0
    cols = [vf, vi, vi, vf]
    ops = ["sum", "sum", "min", "max"]
    accs, pres = _run_fused(impl, keys, cols, ops, num_keys, mask)
    for col, op, acc in zip(cols, ops, accs):
        want = _oracle(keys, col, op, mask, num_keys)
        assert np.asarray(acc).dtype == col.dtype
        np.testing.assert_allclose(
            np.asarray(acc, np.float64), want.astype(np.float64), rtol=1e-5, atol=1e-5
        )
    np.testing.assert_array_equal(
        np.asarray(pres), np.bincount(keys[mask], minlength=num_keys)
    )


@pytest.mark.parametrize("impl", ["pallas", "jnp"])
@pytest.mark.parametrize("n_chunks", [1, 3, 8])
def test_fused_partial_merge_associativity(rng, impl, n_chunks):
    """Chunked partial merge (the partitioned runtime's reduction) is
    equivalent to one whole-table pass: split rows into K chunks, run the
    fused kernel per chunk, merge each accumulator under its own op and
    presence under +."""
    n, num_keys = 3000, 32
    keys = rng.integers(0, num_keys, n).astype(np.int32)
    vi = rng.integers(-100, 100, n).astype(np.int32)
    vf = rng.normal(size=n).astype(np.float32)
    mask = rng.integers(0, 3, n) > 0
    cols = [vi, vf, vi]
    ops = ["sum", "max", "min"]

    whole_accs, whole_pres = _run_fused(impl, keys, cols, ops, num_keys, mask)

    bounds = np.linspace(0, n, n_chunks + 1).astype(int)
    accs = [None] * len(ops)
    pres = None
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        part, ppres = _run_fused(
            impl, keys[lo:hi], [c[lo:hi] for c in cols], ops, num_keys, mask[lo:hi]
        )
        for i, op in enumerate(ops):
            a = np.asarray(part[i])
            accs[i] = a if accs[i] is None else _MERGE_NP[op](accs[i], a)
        p = np.asarray(ppres)
        pres = p if pres is None else pres + p
    for i, (op, col) in enumerate(zip(ops, cols)):
        assert accs[i].dtype == col.dtype
        np.testing.assert_allclose(
            accs[i].astype(np.float64),
            np.asarray(whole_accs[i], np.float64),
            rtol=1e-5, atol=1e-5,
        )
    np.testing.assert_array_equal(pres, np.asarray(whole_pres))


# ---------------------------------------------------------------------------
# fused kernel ↔ engine wiring
# ---------------------------------------------------------------------------


def _kernel_db(rng, n=20000):
    from repro.data.multiset import Database, Multiset

    return Database().add(
        Multiset.from_columns(
            "t",
            k=rng.integers(0, 50, n).astype(np.int32),
            v=rng.integers(-100, 100, n).astype(np.int32),
            w=rng.normal(size=n).astype(np.float32),
        )
    )


_MULTI_AGG_SQL = "SELECT k, SUM(v), MIN(v), MAX(w), COUNT(k), AVG(w) FROM t GROUP BY k"


def _rows_close(a, b, tol=1e-3):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        for x, y in zip(ra, rb):
            assert abs(float(x) - float(y)) < tol, (ra, rb)


@pytest.mark.parametrize("where", ["", " WHERE v > 10"])
def test_engine_kernel_matches_dense_monolithic(rng, where):
    """agg_method='kernel' (one fused launch for the whole aggregate group)
    is row-identical to 'dense' through the full SQL lowering."""
    from repro.backends.jax_vec import CodegenChoices, Plan
    from repro.core.transforms import canonicalize_array_names
    from repro.frontends.sql import sql_to_forelem

    db = _kernel_db(rng)
    sql = _MULTI_AGG_SQL.replace(" GROUP BY", where + " GROUP BY")
    p = canonicalize_array_names(sql_to_forelem(sql, {"t": ["k", "v", "w"]}))
    kplan = Plan(p, db, CodegenChoices(agg_method="kernel"))
    # the whole query's aggregates land in ONE fused group, loudly
    assert [len(g) for g in kplan.lowering.fused_groups] == [6]
    assert kplan.lowering.method_notes == []
    _rows_close(
        sorted(Plan(p, db, CodegenChoices(agg_method="dense")).run()["R"]),
        sorted(kplan.run()["R"]),
    )


@pytest.mark.parametrize("jit_chunks,async_dispatch", [(True, False), (False, False), (True, True)])
def test_engine_kernel_matches_dense_partitioned(rng, jit_chunks, async_dispatch):
    """The partitioned runtime dispatches the fused group as ONE unit per
    chunk and partial-merges the multi-accumulator state."""
    from repro.backends.jax_vec import CodegenChoices, Plan
    from repro.backends.partitioned import PartitionedChoices, PartitionedPlan
    from repro.core.transforms import canonicalize_array_names
    from repro.frontends.sql import sql_to_forelem

    db = _kernel_db(rng)
    p = canonicalize_array_names(sql_to_forelem(_MULTI_AGG_SQL, {"t": ["k", "v", "w"]}))
    want = sorted(Plan(p, db, CodegenChoices(agg_method="dense")).run()["R"])
    plan = PartitionedPlan(
        p, db,
        PartitionedChoices(
            base=CodegenChoices(agg_method="kernel"), n_partitions=4,
            jit_chunks=jit_chunks, async_dispatch=async_dispatch,
        ),
    )
    _rows_close(want, sorted(plan.run()["R"]))
    agg_ds = [d for d in plan.dispatch_log if d.op.startswith("agg:")]
    assert agg_ds and all(d.fused and d.n_aggs == 6 for d in agg_ds)
    # run 2 exercises the memoized presence path on the fused kernel
    _rows_close(want, sorted(plan.run()["R"]))


def test_onehot_min_fallback_is_loud(rng):
    """Satellite: an op the requested method cannot evaluate downgrades to
    'dense' — with a method_notes entry the optimizer surfaces into the
    pass trace and Decision.rejections, never silently."""
    from repro.backends.jax_vec import CodegenChoices, Plan
    from repro.core import OptimizeOptions, optimize
    from repro.core.transforms import canonicalize_array_names
    from repro.frontends.sql import sql_to_forelem

    db = _kernel_db(rng, n=2000)
    sql = "SELECT k, MIN(v) FROM t GROUP BY k"
    p = canonicalize_array_names(sql_to_forelem(sql, {"t": ["k", "v", "w"]}))
    plan = Plan(p, db, CodegenChoices(agg_method="onehot"))
    assert any("onehot" in note and "'min'" in note for note in plan.lowering.method_notes)
    # ... and the downgraded execution is still correct
    _rows_close(
        sorted(Plan(p, db, CodegenChoices(agg_method="dense")).run()["R"]),
        sorted(plan.run()["R"]),
    )
    res = optimize(p, db, OptimizeOptions(agg_method="onehot", trace=True))
    assert any("aggregation-method fallback" in t for t in res.trace)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,D,Hkv", [(64, 32, 2), (128, 64, 4), (200, 16, 1)])
@pytest.mark.parametrize("causal,window,cap", [
    (True, 0, 0.0), (False, 0, 0.0), (True, 32, 0.0), (True, 0, 30.0),
])
def test_flash_sweep(rng, S, D, Hkv, causal, window, cap):
    B, H = 2, Hkv * 2
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 scale=D ** -0.5, logit_softcap=cap,
                                 q_block=64, kv_block=64, interpret=True)
    want = attention_ref(q, k, v, causal=causal, window=window, scale=D ** -0.5, logit_softcap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3), (jnp.bfloat16, 3e-2)])
def test_flash_dtypes(rng, dtype, tol):
    B, S, H, Hkv, D = 1, 96, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    got = flash_attention_pallas(q, k, v, scale=D ** -0.5, q_block=32, kv_block=32, interpret=True)
    want = attention_ref(q, k, v, scale=D ** -0.5)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_decode_offset(rng):
    """Sq < Sk (query block at the end of the key range — decode style)."""
    B, Sq, Sk, H, Hkv, D = 1, 8, 128, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, Hkv, D)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, scale=D ** -0.5,
                                 q_block=8, kv_block=32, interpret=True)
    want = attention_ref(q, k, v, causal=True, scale=D ** -0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_flash_matches_model_attention(rng):
    """The Pallas kernel and the model's scan-flash agree."""
    from repro.models.attention import flash_attention_jnp

    B, S, H, Hkv, D = 2, 160, 8, 4, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    a = flash_attention_pallas(q, k, v, scale=D ** -0.5, q_block=64, kv_block=64, interpret=True)
    b = flash_attention_jnp(q, k, v, causal=True, scale=D ** -0.5, q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S", [16, 100, 256])
@pytest.mark.parametrize("K", [16, 64])
@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv6_sweep(rng, S, K, chunk):
    B, H = 2, 3
    r = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32) * 0.5
    k = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32) * 0.5
    v = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32) * 0.5
    lw = -jnp.exp(jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32))
    u = jnp.asarray(rng.normal(size=(H, K)), jnp.float32) * 0.3
    got = wkv6_pallas(r, k, v, lw, u, chunk=chunk, interpret=True)
    want, _ = wkv6_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_wkv6_strong_decay_exactness(rng):
    """Strong decay (w ≈ 0) is the numerically-dangerous regime for chunked
    forms; the log-space pairwise formulation must stay exact."""
    B, S, H, K = 1, 64, 2, 16
    r = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32)
    lw = jnp.full((B, S, H, K), -5.0)  # decay e^-5 per token
    u = jnp.zeros((H, K), jnp.float32)
    got = wkv6_pallas(r, k, v, lw, u, chunk=16, interpret=True)
    want, _ = wkv6_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_wkv6_model_chunked_matches_kernel(rng):
    from repro.models.rwkv6 import _wkv_chunked

    B, S, H, K = 2, 80, 2, 16
    r = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32) * 0.5
    k = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32) * 0.5
    v = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32) * 0.5
    lw = -jnp.exp(jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32))
    u = jnp.asarray(rng.normal(size=(H, K)), jnp.float32) * 0.3
    S0 = jnp.zeros((B, H, K, K), jnp.float32)
    a = wkv6_pallas(r, k, v, lw, u, chunk=16, interpret=True)
    b, _ = _wkv_chunked(r, k, v, lw, u, S0, chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)

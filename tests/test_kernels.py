# Per-kernel validation: shape/dtype sweeps, Pallas (interpret mode) vs the
# pure-jnp oracle, plus hypothesis property tests on segreduce.
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels.segreduce.kernel import segreduce_pallas
from repro.kernels.segreduce.ref import segreduce_ref
from repro.kernels.flash.kernel import flash_attention_pallas
from repro.kernels.flash.ref import attention_ref
from repro.kernels.wkv6.kernel import wkv6_pallas
from repro.kernels.wkv6.ref import wkv6_ref

# ---------------------------------------------------------------------------
# segreduce
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [8, 100, 1024, 5000])
@pytest.mark.parametrize("k", [1, 7, 128, 1000])
@pytest.mark.parametrize("op", ["sum", "max"])
def test_segreduce_sweep(rng, n, k, op):
    keys = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    vals = jnp.asarray(rng.normal(size=n), jnp.float32)
    got = segreduce_pallas(keys, vals, k, op=op, interpret=True)
    want = segreduce_ref(keys, vals, k, op=op)
    if op == "max":
        # empty segments: kernel yields -inf sentinel, ref yields -inf
        mask = np.asarray(segreduce_ref(keys, jnp.ones_like(vals), k)) > 0
        np.testing.assert_allclose(np.asarray(got)[mask], np.asarray(want)[mask], rtol=1e-6, atol=1e-6)
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_segreduce_dtypes(rng, dtype):
    keys = jnp.asarray(rng.integers(0, 33, 500), jnp.int32)
    vals = jnp.asarray(rng.integers(0, 10, 500)).astype(dtype)
    got = segreduce_pallas(keys, vals, 33, interpret=True)
    want = segreduce_ref(keys, vals.astype(jnp.float32), 33)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-2, atol=1e-2)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 2000), k=st.integers(1, 300), seed=st.integers(0, 99))
def test_property_segreduce_equals_ref(n, k, seed):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    vals = jnp.asarray(rng.normal(size=n), jnp.float32)
    got = segreduce_pallas(keys, vals, k, interpret=True)
    want = segreduce_ref(keys, vals, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,D,Hkv", [(64, 32, 2), (128, 64, 4), (200, 16, 1)])
@pytest.mark.parametrize("causal,window,cap", [
    (True, 0, 0.0), (False, 0, 0.0), (True, 32, 0.0), (True, 0, 30.0),
])
def test_flash_sweep(rng, S, D, Hkv, causal, window, cap):
    B, H = 2, Hkv * 2
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 scale=D ** -0.5, logit_softcap=cap,
                                 q_block=64, kv_block=64, interpret=True)
    want = attention_ref(q, k, v, causal=causal, window=window, scale=D ** -0.5, logit_softcap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3), (jnp.bfloat16, 3e-2)])
def test_flash_dtypes(rng, dtype, tol):
    B, S, H, Hkv, D = 1, 96, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    got = flash_attention_pallas(q, k, v, scale=D ** -0.5, q_block=32, kv_block=32, interpret=True)
    want = attention_ref(q, k, v, scale=D ** -0.5)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_decode_offset(rng):
    """Sq < Sk (query block at the end of the key range — decode style)."""
    B, Sq, Sk, H, Hkv, D = 1, 8, 128, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, Hkv, D)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, scale=D ** -0.5,
                                 q_block=8, kv_block=32, interpret=True)
    want = attention_ref(q, k, v, causal=True, scale=D ** -0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_flash_matches_model_attention(rng):
    """The Pallas kernel and the model's scan-flash agree."""
    from repro.models.attention import flash_attention_jnp

    B, S, H, Hkv, D = 2, 160, 8, 4, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    a = flash_attention_pallas(q, k, v, scale=D ** -0.5, q_block=64, kv_block=64, interpret=True)
    b = flash_attention_jnp(q, k, v, causal=True, scale=D ** -0.5, q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S", [16, 100, 256])
@pytest.mark.parametrize("K", [16, 64])
@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv6_sweep(rng, S, K, chunk):
    B, H = 2, 3
    r = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32) * 0.5
    k = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32) * 0.5
    v = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32) * 0.5
    lw = -jnp.exp(jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32))
    u = jnp.asarray(rng.normal(size=(H, K)), jnp.float32) * 0.3
    got = wkv6_pallas(r, k, v, lw, u, chunk=chunk, interpret=True)
    want, _ = wkv6_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_wkv6_strong_decay_exactness(rng):
    """Strong decay (w ≈ 0) is the numerically-dangerous regime for chunked
    forms; the log-space pairwise formulation must stay exact."""
    B, S, H, K = 1, 64, 2, 16
    r = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32)
    lw = jnp.full((B, S, H, K), -5.0)  # decay e^-5 per token
    u = jnp.zeros((H, K), jnp.float32)
    got = wkv6_pallas(r, k, v, lw, u, chunk=16, interpret=True)
    want, _ = wkv6_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_wkv6_model_chunked_matches_kernel(rng):
    from repro.models.rwkv6 import _wkv_chunked

    B, S, H, K = 2, 80, 2, 16
    r = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32) * 0.5
    k = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32) * 0.5
    v = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32) * 0.5
    lw = -jnp.exp(jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32))
    u = jnp.asarray(rng.normal(size=(H, K)), jnp.float32) * 0.3
    S0 = jnp.zeros((B, H, K, K), jnp.float32)
    a = wkv6_pallas(r, k, v, lw, u, chunk=16, interpret=True)
    b, _ = _wkv_chunked(r, k, v, lw, u, S0, chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)

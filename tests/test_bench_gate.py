# Unit tests for the CI benchmark-regression gate
# (benchmarks/check_regression.py): metric extraction, tolerance math, and
# the exit status CI keys on.
import importlib.util
import json
import os
import sys

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "check_regression",
    os.path.join(os.path.dirname(__file__), "..", "benchmarks", "check_regression.py"),
)
gate = importlib.util.module_from_spec(_SPEC)
sys.modules["check_regression"] = gate  # dataclass resolution needs the registry
_SPEC.loader.exec_module(gate)


def _write(dirpath, name, payload):
    os.makedirs(dirpath, exist_ok=True)
    with open(os.path.join(dirpath, name), "w") as f:
        json.dump(payload, f)


def _engine_report(speedups):
    return {"queries": [{"warm_vs_cold_speedup": s} for s in speedups]}


def _partition_report(ratios):
    return {"key_ratios": ratios}


def test_geomean_extraction(tmp_path):
    _write(tmp_path, "BENCH_engine.json", _engine_report([4.0, 9.0]))
    m = gate.load_metrics(str(tmp_path / "BENCH_engine.json"))
    assert m["warm_vs_cold_speedup"] == pytest.approx(6.0)  # sqrt(4*9)


def test_missing_file_returns_none(tmp_path):
    assert gate.load_metrics(str(tmp_path / "BENCH_engine.json")) is None


def test_within_tolerance_passes(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    _write(base, "BENCH_engine.json", _engine_report([6.0]))
    _write(fresh, "BENCH_engine.json", _engine_report([4.5]))  # 6/1.5 = 4.0 floor
    comps = gate.compare(str(fresh), str(base), tolerance=1.5)
    assert len(comps) == 1 and not comps[0].regressed
    assert gate.main([f"--baseline-dir={base}", f"--fresh-dir={fresh}"]) == 0


def test_regression_fails(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    _write(base, "BENCH_engine.json", _engine_report([6.0]))
    _write(fresh, "BENCH_engine.json", _engine_report([3.0]))  # below 4.0 floor
    comps = gate.compare(str(fresh), str(base), tolerance=1.5)
    assert comps[0].regressed
    assert gate.main([f"--baseline-dir={base}", f"--fresh-dir={fresh}"]) == 1


def test_missing_fresh_report_is_a_regression(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    _write(base, "BENCH_engine.json", _engine_report([6.0]))
    os.makedirs(fresh, exist_ok=True)
    assert gate.main([f"--baseline-dir={base}", f"--fresh-dir={fresh}"]) == 1


def test_no_baseline_is_not_gated(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    os.makedirs(base, exist_ok=True)
    _write(fresh, "BENCH_engine.json", _engine_report([6.0]))
    assert gate.compare(str(fresh), str(base), tolerance=1.5) == []
    assert gate.main([f"--baseline-dir={base}", f"--fresh-dir={fresh}"]) == 0
    # ... unless CI demands baselines: a missing/misconfigured baseline dir
    # must fail loudly, not pass as a silent no-op
    assert gate.main(["--require-baselines",
                      f"--baseline-dir={base}", f"--fresh-dir={fresh}"]) == 2


def test_committed_baselines_exist_and_are_tracked():
    # regression guard for the .gitignore trap: the unanchored BENCH_*.json
    # patterns used to ignore benchmarks/baselines/*.json too, leaving the
    # CI gate with nothing to compare against
    import subprocess

    root = os.path.join(os.path.dirname(__file__), "..")
    bdir = os.path.join(root, "benchmarks", "baselines")
    names = sorted(os.listdir(bdir))
    assert names, "no committed baselines"
    out = subprocess.run(
        ["git", "check-ignore"] + [os.path.join("benchmarks", "baselines", n) for n in names],
        cwd=root, capture_output=True, text=True,
    )
    assert out.returncode != 0, f"baselines are gitignored: {out.stdout}"


def test_partition_key_ratios_gated_individually(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    _write(base, "BENCH_partition.json",
           _partition_report({"agg_uniform_mono_vs_partitioned": 1.0, "join_mono_vs_partitioned": 2.0}))
    _write(fresh, "BENCH_partition.json",
           _partition_report({"agg_uniform_mono_vs_partitioned": 0.9, "join_mono_vs_partitioned": 1.0}))
    comps = {c.metric: c for c in gate.compare(str(fresh), str(base), tolerance=1.5)}
    assert not comps["agg_uniform_mono_vs_partitioned"].regressed  # 0.9 >= 1.0/1.5
    assert comps["join_mono_vs_partitioned"].regressed             # 1.0 <  2.0/1.5


def test_compile_counts_gated_lower_is_better(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    payload = {"key_ratios": {"agg_uniform_mono_vs_partitioned": 1.5},
               "key_counts": {"agg_uniform_jit_compiles": 8}}
    _write(base, "BENCH_partition.json", payload)
    # compile count exploded 10x while the ratio stayed fine: must fail
    _write(fresh, "BENCH_partition.json",
           {"key_ratios": {"agg_uniform_mono_vs_partitioned": 1.5},
            "key_counts": {"agg_uniform_jit_compiles": 80}})
    comps = {c.metric: c for c in gate.compare(str(fresh), str(base), tolerance=2.0)}
    assert comps["agg_uniform_jit_compiles"].lower_is_better
    assert comps["agg_uniform_jit_compiles"].regressed       # 80 > 8 * 2.0
    assert not comps["agg_uniform_mono_vs_partitioned"].regressed
    assert gate.main(["--tolerance=2.0",
                      f"--baseline-dir={base}", f"--fresh-dir={fresh}"]) == 1
    # fewer compiles than baseline is an improvement, not a regression
    _write(fresh, "BENCH_partition.json",
           {"key_ratios": {"agg_uniform_mono_vs_partitioned": 1.5},
            "key_counts": {"agg_uniform_jit_compiles": 2}})
    assert gate.main(["--tolerance=2.0",
                      f"--baseline-dir={base}", f"--fresh-dir={fresh}"]) == 0


def test_tolerance_is_configurable(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    _write(base, "BENCH_engine.json", _engine_report([6.0]))
    _write(fresh, "BENCH_engine.json", _engine_report([3.5]))
    assert gate.main(["--tolerance=1.5", f"--baseline-dir={base}", f"--fresh-dir={fresh}"]) == 1
    assert gate.main(["--tolerance=2.0", f"--baseline-dir={base}", f"--fresh-dir={fresh}"]) == 0

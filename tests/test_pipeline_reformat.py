# Data pipeline (tokenize/pack/load) and reformatting (§III-C1) invariants.
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import OptimizeOptions, optimize
from repro.core.reformat import apply_reformat, plan_reformat
from repro.data.multiset import (
    CompressedRangeColumn,
    Database,
    DictColumn,
    Multiset,
    PlainColumn,
    dict_encode,
)
from repro.data.pipeline import PipelineConfig, ShardedLoader, Vocab, build_dataset, build_vocab, tokenize
from repro.frontends.sql import sql_to_forelem


# ---------------------------------------------------------------------------
# reformatting
# ---------------------------------------------------------------------------


def test_dict_encode_roundtrip(rng):
    vals = np.array([f"s{i%7}" for i in rng.integers(0, 100, 200)], dtype=object)
    col = dict_encode(vals)
    assert col.num_keys == len(np.unique(vals))
    assert (col.decode() == vals).all()


def test_compressed_range_column():
    ms = Multiset.from_columns("t", ts=np.arange(10, 1000, 3, dtype=np.int64), x=np.zeros(330, np.int32))
    c = ms.reformat_compress_ranges()
    assert isinstance(c.columns["ts"], CompressedRangeColumn)
    np.testing.assert_array_equal(c.field("ts"), ms.field("ts"))
    assert c.columns["ts"].nbytes < ms.columns["ts"].nbytes


def test_reformat_planner_prunes_and_encodes(rng):
    urls = np.array([f"u{i%9}" for i in range(500)], dtype=object)
    db = Database().add(Multiset("logs", {
        "url": PlainColumn(urls),
        "unused": PlainColumn(rng.integers(0, 10, 500)),
    }))
    prog = sql_to_forelem("SELECT url, COUNT(url) FROM logs GROUP BY url", {"logs": ["url", "unused"]})
    plan = plan_reformat(prog, db)
    actions = {a.action for a in plan.actions}
    assert "prune" in actions and "dict_encode" in actions
    db2 = apply_reformat(plan, db)
    assert "unused" not in db2["logs"].field_names()
    assert isinstance(db2["logs"].columns["url"], DictColumn)
    assert db2["logs"].nbytes < db["logs"].nbytes


def test_amortization_gate():
    # repetitive strings: dictionary encoding shrinks the column -> pays off
    urls = np.array([f"http://long-host-name-{i % 10}.example.com/path" for i in range(2000)], dtype=object)
    db = Database().add(Multiset("t", {"url": PlainColumn(urls)}))
    prog = sql_to_forelem("SELECT url, COUNT(url) FROM t GROUP BY url", {"t": ["url"]})
    plan = plan_reformat(prog, db)
    assert plan.per_run_bytes_saved > 0
    assert plan.worthwhile(expected_runs=1000)
    assert plan.oneoff_bytes > 0

    # all-unique strings: encoding does not shrink -> planner reports no
    # per-run saving (the paper's 'prohibitively expensive' case)
    uniq = np.array([f"u{i}" for i in range(100)], dtype=object)
    db2 = Database().add(Multiset("t", {"url": PlainColumn(uniq)}))
    plan2 = plan_reformat(prog, db2)
    assert plan2.per_run_bytes_saved == 0


def test_optimize_reformats_then_answers_match_python(rng):
    urls = np.array([f"http://h{i%13}/p" for i in rng.integers(0, 300, 2000)], dtype=object)
    db = Database().add(Multiset("access", {"url": PlainColumn(urls)}))
    prog = sql_to_forelem("SELECT url, COUNT(url) FROM access GROUP BY url", {"access": ["url"]})
    res = optimize(prog, db, OptimizeOptions(n_parts=4))
    got = res.plan.run()["R"]
    # decode integer keys back to strings and compare against numpy
    dcol = res.db["access"].columns["url"]
    want = {u: c for u, c in zip(*np.unique(urls, return_counts=True))}
    for code, count in got:
        assert want[dcol.dictionary[code]] == count


# ---------------------------------------------------------------------------
# LM pipeline
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    n_docs=st.integers(1, 60),
    seq_len=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 99),
)
def test_property_packing_invariants(n_docs, seq_len, seed):
    rng = np.random.default_rng(seed)
    docs = [" ".join(f"w{x}" for x in rng.integers(0, 50, rng.integers(1, 80))) for _ in range(n_docs)]
    ds = build_dataset(docs, PipelineConfig(seq_len=seq_len, min_doc_tokens=4, vocab_size=256))
    # all ids within vocab; pad only at the tail row; loss mask matches pad
    assert ds.tokens.max() < ds.vocab.size
    assert ds.tokens.min() >= 0
    assert ((ds.tokens == Vocab.PAD) == ~ds.loss_mask).all()
    assert ds.tokens.shape[1] == seq_len
    # token conservation: every kept doc contributes len+2 tokens
    kept = [d for d in docs if len(d.split()) >= 4]
    expect = sum(len(d.split()) + 2 for d in kept)
    assert ds.loss_mask.sum() == expect


def test_vocab_specials_and_unk():
    v = build_vocab(["a b c a"], max_size=6)
    assert v.id_to_token[:4] == ["<pad>", "<bos>", "<eos>", "<unk>"]
    ids = tokenize("a z", v)
    assert ids[0] >= 4 and ids[1] == Vocab.UNK


def test_loader_determinism_and_sharding():
    rng = np.random.default_rng(0)
    docs = [" ".join(f"w{x}" for x in rng.integers(0, 50, 60)) for _ in range(100)]
    ds = build_dataset(docs, PipelineConfig(seq_len=64, min_doc_tokens=4))
    l1 = ShardedLoader(ds, global_batch=8, n_shards=4, shard=1, seed=7)
    l2 = ShardedLoader(ds, global_batch=8, n_shards=4, shard=1, seed=7)
    b1, b2 = l1.batch(3), l2.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    s = l1.shard_slice(b1)
    assert s["tokens"].shape[0] == 2
    chunks = l1.chunks(total_steps=10, chunk_size=4)
    assert chunks == [(0, 4), (4, 4), (8, 2)]

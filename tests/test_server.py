# Multi-tenant serving engine (engine/server.py): admission control,
# shared plan cache with single-flight compilation, fault-tolerant chunk
# dispatch on the shared worker pool, and elastic pool scaling.
#
# The centerpiece is the concurrency stress test: N tenant threads × M
# queries through one QueryServer with injected chunk faults and a
# straggler, asserting results stay bit-identical to serial execution,
# retries are bounded, coverage holds (every chunk of every op executed
# exactly once), and the plan cache compiled each distinct logical query
# exactly once.
import threading
import time

import numpy as np
import pytest

from repro import AdmissionError, QueryServer, Session
from repro.engine.server import SharedChunkPool
from repro.sched.elastic import PoolScalePolicy
from repro.sched.fault_tolerant import (
    ChunkRetryExceeded,
    FTResult,
    RetryPolicy,
    deterministic_fault_hook,
    verify_coverage,
)

N_ROWS = 30_000


def _tables(seed=0):
    rng = np.random.default_rng(seed)
    i32 = np.int32
    return {
        "access": dict(
            url=rng.integers(0, 40, N_ROWS).astype(i32),
            uid=rng.integers(0, 300, N_ROWS).astype(i32),
            size=rng.integers(1, 1000, N_ROWS).astype(i32),
        ),
        "users": dict(
            uid=np.arange(300, dtype=i32),
            region=rng.integers(0, 5, 300).astype(i32),
        ),
    }


# a mixed aggregate/join workload: three distinct logical queries
QUERIES = [
    "SELECT url, COUNT(url) FROM access GROUP BY url",
    "SELECT url, SUM(size) FROM access GROUP BY url",
    "SELECT u.region, COUNT(u.region), SUM(a.size) FROM access a, users u "
    "WHERE a.uid = u.uid GROUP BY u.region",
]


def _server(**kw):
    kw.setdefault("n_partitions", 4)
    srv = QueryServer(**kw)
    for name, cols in _tables().items():
        srv.register(name, **cols)
    return srv


def _serial_results():
    s = Session(backend="partitioned", n_partitions=4, async_dispatch=False)
    for name, cols in _tables().items():
        s.register(name, **cols)
    return {q: sorted(s.sql(q).rows) for q in QUERIES}


@pytest.fixture(scope="module")
def serial():
    return _serial_results()


# ---------------------------------------------------------------------------
# The stress test
# ---------------------------------------------------------------------------


def test_concurrent_tenants_faults_and_straggler(serial):
    """8 tenants × 6 queries each, 8% injected chunk-fault rate plus one
    slow chunk: every query completes, every result is bit-identical to
    serial, retries stay bounded, chunk coverage holds per op, and the
    plan cache compiled each distinct query exactly once."""
    inject = deterministic_fault_hook(0.08, seed=3)
    slow_hit = threading.Event()

    def hook(d):
        # one straggling chunk (first attempt only) + deterministic faults
        if d.op.startswith("agg:") and d.partition == 1 and d.attempt == 0 and not slow_hit.is_set():
            slow_hit.set()
            time.sleep(0.25)
        inject(d)

    srv = _server(
        fault=RetryPolicy(max_retries=2, fault_hook=hook),
        scale=PoolScalePolicy(min_workers=2, max_workers=4),
        max_pending=16,
        admission="block",
    )
    n_tenants, n_queries = 8, 6
    errors = []
    logs = []  # (query, [ChunkDispatch...]) per run, collected per thread
    lock = threading.Lock()

    def tenant(tid):
        try:
            for j in range(n_queries):
                q = QUERIES[(tid + j) % len(QUERIES)]
                r = srv.submit(q, tenant=f"t{tid}", priority=tid % 3)
                rows = sorted(r.rows)
                # dispatch_log is thread-local per run: read it on the
                # submitting thread, right after the run
                log = list(r.plan.dispatch_log)
                with lock:
                    logs.append((q, log))
                if rows != serial[q]:
                    raise AssertionError(f"tenant {tid} query {j}: result diverged from serial")
        except BaseException as e:  # noqa: BLE001 - collected for the main thread
            errors.append(e)

    threads = [threading.Thread(target=tenant, args=(i,)) for i in range(n_tenants)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    try:
        assert not errors, errors
        assert len(logs) == n_tenants * n_queries
        # bounded retries: no chunk ever exceeded max_retries attempts
        for _, log in logs:
            for d in log:
                assert d.attempt <= 2
        # coverage: per run and per op, completed chunk starts tile
        # [0, total rows) exactly once (the simulator's verify_coverage
        # applied to real dispatch records)
        for _, log in logs:
            per_op = {}
            for d in log:
                per_op.setdefault(d.op, []).append(d)
            for ds in per_op.values():
                total = sum(d.rows for d in ds)
                res = FTResult(
                    makespan=0.0,
                    events=[],
                    completed={d.start: d.worker for d in ds},
                    duplicated_work=0,
                    lost_work=0,
                    checkpoints=0,
                )
                assert verify_coverage(res, total)
        # single-flight + shared cache: one compile per distinct query
        st = srv.plan_cache.stats()
        assert st["misses"] == len(QUERIES)
        # the injected faults actually exercised the retry path
        assert srv.metrics.counter("serve.chunk.retries") > 0
        assert srv.metrics.counter("serve.admitted") == n_tenants * n_queries
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------


def test_admission_reject_when_full():
    srv = _server(max_pending=1, admission="reject")
    try:
        srv._admit("a", 0)  # occupy the only slot
        with pytest.raises(AdmissionError):
            srv.submit(QUERIES[0], tenant="b")
        assert srv.metrics.counter("serve.rejected") == 1
        srv._release()
        assert sorted(srv.submit(QUERIES[0], tenant="b").rows) == _serial_results()[QUERIES[0]]
    finally:
        srv.close()


def test_admission_block_waits_for_slot():
    srv = _server(max_pending=1, admission="block")
    try:
        srv._admit("a", 0)
        got = []
        t = threading.Thread(
            target=lambda: got.append(srv.submit(QUERIES[0], tenant="b"))
        )
        t.start()
        time.sleep(0.1)
        assert not got  # still blocked on admission
        assert srv.metrics.counter("serve.blocked") == 1
        srv._release()
        t.join(timeout=30)
        assert got and got[0].rows is not None
    finally:
        srv.close()


def test_block_mode_full_load_completes(serial):
    srv = _server(max_pending=2, admission="block")
    errors = []

    def go(i):
        try:
            q = QUERIES[i % len(QUERIES)]
            assert sorted(srv.submit(q, tenant=f"t{i}").rows) == serial[q]
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=go, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    try:
        assert not errors, errors
        assert srv.metrics.counter("serve.admitted") == 8
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# Fault handling
# ---------------------------------------------------------------------------


def test_retry_exhaustion_raises():
    # every attempt of every chunk faults (max_faulty_attempts > max_retries)
    srv = _server(
        fault=RetryPolicy(
            max_retries=1,
            speculate=False,
            fault_hook=deterministic_fault_hook(1.0, max_faulty_attempts=5),
        )
    )
    try:
        with pytest.raises(ChunkRetryExceeded):
            srv.submit(QUERIES[0])
        assert srv.metrics.counter("serve.chunk.retries") > 0
    finally:
        srv.close()


def test_zero_fault_rate_means_zero_retries(serial):
    srv = _server(fault=RetryPolicy(max_retries=2, fault_hook=deterministic_fault_hook(0.0)))
    try:
        for q in QUERIES:
            assert sorted(srv.submit(q).rows) == serial[q]
        assert srv.metrics.counter("serve.chunk.retries") == 0
    finally:
        srv.close()


def test_serial_session_fault_path(serial):
    """The local (non-server) dispatch path honors the same RetryPolicy:
    a Session with an attached fault policy retries failing chunks."""
    s = Session(
        backend="partitioned",
        n_partitions=4,
        async_dispatch=False,
        fault=RetryPolicy(max_retries=2, fault_hook=deterministic_fault_hook(0.3, seed=1)),
    )
    for name, cols in _tables().items():
        s.register(name, **cols)
    r = s.sql(QUERIES[0])
    assert sorted(r.rows) == serial[QUERIES[0]]
    assert r.plan.fault_stats.retries > 0
    assert all(d.attempt <= 2 for d in r.plan.dispatch_log)


def test_local_pool_fault_path(serial):
    """The per-query worker pool (async_dispatch with explicit n_workers —
    cpu_count may be 1 in CI) re-queues failed chunks instead of dying."""
    s = Session(
        backend="partitioned",
        n_partitions=4,
        fault=RetryPolicy(max_retries=2, fault_hook=deterministic_fault_hook(0.3, seed=1)),
    )
    for name, cols in _tables().items():
        s.register(name, **cols)
    r0 = s.sql(QUERIES[0])  # compile once
    r0.plan.choices.n_workers = 3
    r0.plan.choices.async_dispatch = True
    r = s.sql(QUERIES[0])
    assert sorted(r.rows) == serial[QUERIES[0]]
    assert r.plan.fault_stats.retries > 0


# ---------------------------------------------------------------------------
# Elastic pool scaling
# ---------------------------------------------------------------------------


def test_pool_scales_up_and_down():
    policy = PoolScalePolicy(min_workers=1, max_workers=4, queue_high=1.0, idle_timeout=0.05)
    pool = SharedChunkPool(policy)
    try:
        def work(ch):
            time.sleep(0.01)
            return ch[2]

        from repro.backends.partitioned import ChunkDispatch

        chunks = [(0, None, ChunkDispatch("op", 0, 1, 0, start=i)) for i in range(16)]
        out = pool.run_chunks(chunks, work)
        assert len(out) == 16
        kinds = [e.kind for e in policy.events]
        assert "up" in kinds  # queue pressure grew the pool
        deadline = time.time() + 5.0
        while pool.n_workers > 1 and time.time() < deadline:
            time.sleep(0.02)
        assert pool.n_workers == 1  # idle workers retired to min_workers
        assert "down" in [e.kind for e in policy.events]
    finally:
        pool.close()


def test_speculation_on_straggler():
    """A chunk an order of magnitude slower than the median gets one
    speculative backup; the backup's result wins and work completes."""
    policy = PoolScalePolicy(min_workers=3, max_workers=3)
    pool = SharedChunkPool(policy)
    try:
        from repro.backends.partitioned import ChunkDispatch

        def hook(d):
            if d.start == 0 and not d.speculated:
                time.sleep(0.5)  # primary of chunk 0 straggles

        fault = RetryPolicy(max_retries=1, speculate=True, straggler_factor=4.0,
                            min_completed=3, fault_hook=hook)
        chunks = [(0, None, ChunkDispatch("op", 0, 1, 0, start=i)) for i in range(12)]

        def work(ch):
            time.sleep(0.01)
            return ch[2].start

        out = pool.run_chunks(chunks, work, fault=fault)
        assert out == list(range(12))
        assert chunks[0][2].speculated
    finally:
        pool.close()


# ---------------------------------------------------------------------------
# Shared state under concurrency (the bugfix satellite's regression harness)
# ---------------------------------------------------------------------------


def test_plan_cache_concurrent_mutation():
    from repro.planner.cache import CacheEntry, PlanCache

    cache = PlanCache(capacity=32)
    errors = []

    def pound(tid):
        try:
            for i in range(400):
                k = f"fp{(tid * 400 + i) % 64}"
                if cache.get(k, "e") is None:
                    cache.put(k, "e", CacheEntry(None, None, "", None, "e"))
                if i % 50 == 0:
                    cache.stats()
                    len(cache)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=pound, args=(t,)) for t in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert len(cache) <= 32
    st = cache.stats()
    assert st["hits"] + st["misses"] == 8 * 400


def test_metrics_registry_concurrent_counts():
    from repro.obs import MetricsRegistry

    m = MetricsRegistry()

    def bump():
        for _ in range(1000):
            m.inc("c")
            m.observe("h", 1.0)

    threads = [threading.Thread(target=bump) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert m.counter("c") == 8000
    assert m.snapshot()["histograms"]["h"]["count"] == 8000


def test_tenant_isolation_and_shared_cache(serial):
    """Tenants see their own query logs but share one compiled plan."""
    srv = _server()
    try:
        srv.submit(QUERIES[0], tenant="alice")
        srv.submit(QUERIES[0], tenant="bob")
        assert len(srv.session("alice").query_log) == 1
        assert len(srv.session("bob").query_log) == 1
        assert srv.tenants() == ["alice", "bob"]
        st = srv.plan_cache.stats()
        assert st["misses"] == 1 and st["hits"] >= 1
    finally:
        srv.close()

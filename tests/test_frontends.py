# SQL / MapReduce frontends and the §IV forelem→MapReduce export.
import numpy as np
import pytest

from repro.core.lower import Plan, ReferenceInterpreter
from repro.data.multiset import Database, Multiset
from repro.frontends.export_mr import NotMapReduceShape, forelem_to_mapreduce
from repro.frontends.mapreduce import (
    MapReduceSpec,
    count_reduce,
    mapreduce_to_forelem,
    run_python_mapreduce,
    sum_reduce,
    wordcount_map,
)
from repro.frontends.sql import SQLError, parse_sql, sql_to_forelem
from repro.core.ir import FieldRef


@pytest.fixture
def web_db(rng):
    urls = rng.integers(0, 15, 500).astype(np.int32)
    return Database().add(Multiset.from_columns("access", url=urls)), urls


def _ref(p, db, params=None):
    out = ReferenceInterpreter(db, params).run(p)
    return {k: sorted(v) if isinstance(v, list) else v for k, v in out.items()}


def test_paper_query_urlcount(web_db):
    db, urls = web_db
    p = sql_to_forelem("SELECT url, COUNT(url) FROM access GROUP BY url", {"access": ["url"]})
    got = sorted(Plan(p, db).run()["R"])
    vals, counts = np.unique(urls, return_counts=True)
    assert got == [(int(v), int(c)) for v, c in zip(vals, counts)]


def test_paper_query_weblink(rng):
    src = rng.integers(0, 40, 600).astype(np.int32)
    tgt = rng.integers(0, 25, 600).astype(np.int32)
    db = Database().add(Multiset.from_columns("links", source=src, target=tgt))
    p = sql_to_forelem("SELECT target, COUNT(target) FROM links GROUP BY target",
                       {"links": ["source", "target"]})
    got = sorted(Plan(p, db).run()["R"])
    vals, counts = np.unique(tgt, return_counts=True)
    assert got == [(int(v), int(c)) for v, c in zip(vals, counts)]


def test_sql_aggregates_sum_min_max_avg(rng):
    k = rng.integers(0, 6, 300).astype(np.int32)
    v = rng.integers(0, 100, 300).astype(np.int32)
    db = Database().add(Multiset.from_columns("t", k=k, v=v))
    p = sql_to_forelem("SELECT k, SUM(v), MIN(v), MAX(v) FROM t GROUP BY k", {"t": ["k", "v"]})
    got = {r[0]: r[1:] for r in Plan(p, db).run()["R"]}
    for key in np.unique(k):
        sel = v[k == key]
        assert got[int(key)] == (int(sel.sum()), int(sel.min()), int(sel.max()))


def test_sql_where_and_params(rng):
    k = rng.integers(0, 6, 200).astype(np.int32)
    v = rng.integers(0, 100, 200).astype(np.int32)
    db = Database().add(Multiset.from_columns("t", k=k, v=v))
    p = sql_to_forelem("SELECT SUM(v) FROM t WHERE k = :kk", {"t": ["k", "v"]})
    got = Plan(p, db).run(params={"kk": 3})
    assert got["scalar"] == int(v[k == 3].sum())


def test_sql_join(rng):
    A = Multiset.from_columns("A", b_id=rng.integers(0, 50, 80).astype(np.int32),
                              f=rng.integers(0, 9, 80).astype(np.int32))
    B = Multiset.from_columns("B", id=np.arange(50).astype(np.int32),
                              g=rng.integers(0, 9, 50).astype(np.int32))
    db = Database().add(A).add(B)
    p = sql_to_forelem("SELECT a.f, b.g FROM A a, B b WHERE a.b_id = b.id",
                       {"A": ["b_id", "f"], "B": ["id", "g"]})
    assert sorted(Plan(p, db).run()["R"]) == _ref(p, db)["R"]


def test_sql_parse_errors():
    with pytest.raises(SQLError):
        parse_sql("SELECT FROM nothing")
    with pytest.raises(SQLError):
        sql_to_forelem("SELECT a FROM t1, t2, t3", {"t1": ["a"], "t2": ["a"], "t3": ["a"]})


def test_forelem_to_mapreduce_roundtrip(web_db):
    db, urls = web_db
    p = sql_to_forelem("SELECT url, COUNT(url) FROM access GROUP BY url", {"access": ["url"]})
    mr = forelem_to_mapreduce(p)
    assert "emitIntermediate" in mr.pseudocode
    rows = [(i, {"url": int(u)}) for i, u in enumerate(urls)]
    mr_out = run_python_mapreduce(mr.map_fn, mr.reduce_fn, rows, num_reducers=4)
    assert sorted(mr_out) == sorted(Plan(p, db).run()["R"])


def test_forelem_to_mapreduce_sum_variant(rng):
    k = rng.integers(0, 8, 200).astype(np.int32)
    v = rng.integers(0, 10, 200).astype(np.int32)
    db = Database().add(Multiset.from_columns("T", f1=k, f2=v))
    spec = MapReduceSpec("T", "f1", FieldRef("T", "i", "f2"))
    p = mapreduce_to_forelem(spec, ["f1", "f2"])
    mr = forelem_to_mapreduce(p)
    rows = [(i, {"f1": int(a), "f2": int(b)}) for i, (a, b) in enumerate(zip(k, v))]
    mr_out = run_python_mapreduce(mr.map_fn, mr.reduce_fn, rows, 4)
    assert sorted(mr_out) == sorted(Plan(p, db).run()["R"])


def test_non_mr_shape_rejected(rng):
    p = sql_to_forelem("SELECT k FROM t", {"t": ["k"]})
    with pytest.raises(NotMapReduceShape):
        forelem_to_mapreduce(p)


def test_python_mapreduce_wordcount():
    lines = ["a b a", "b c", "a"]
    out = run_python_mapreduce(wordcount_map, count_reduce, enumerate(lines), 2)
    assert sorted(out) == [("a", 3), ("b", 2), ("c", 1)]
    out2 = run_python_mapreduce(lambda k, v: [(v, 2)], sum_reduce, enumerate(["x", "x", "y"]), 1)
    assert sorted(out2) == [("x", 4), ("y", 2)]

# Cost-based planner (repro.planner): statistics, cardinality-estimate
# accuracy vs. actual row counts, cost-model ranking sanity (the chosen plan
# must not be slower than the worst enumerated plan), join-order
# interchange, plan-cache hit/invalidation on stats-epoch change, EXPLAIN,
# and SQL ORDER BY / LIMIT end to end.
import time

import numpy as np
import pytest

import jax

from repro.core import OptimizeOptions, optimize
from repro.core.lower import CodegenChoices, Plan, ReferenceInterpreter
from repro.core.transforms import join_orders
from repro.data.multiset import Database, Multiset
from repro.frontends.sql import SQLError, sql_to_forelem
from repro.planner import (
    CardinalityEstimator,
    PlanCache,
    collect_stats,
    enumerate_candidates,
    plan_query,
    program_fingerprint,
    render_explain,
)


@pytest.fixture
def db(rng):
    k = rng.integers(0, 50, 4000).astype(np.int32)
    v = rng.integers(0, 100, 4000).astype(np.int32)
    return Database().add(Multiset.from_columns("t", k=k, v=v)), k, v


# ---------------------------------------------------------------------------
# statistics
# ---------------------------------------------------------------------------


def test_stats_basic(db):
    d, k, v = db
    stats = collect_stats(d)
    ts = stats.table("t")
    assert ts.n_rows == 4000
    fk = ts.field_stats("k")
    assert fk.n_distinct == len(np.unique(k))
    assert fk.vmin == float(k.min()) and fk.vmax == float(k.max())
    assert sum(fk.hist_counts) == pytest.approx(4000, rel=0.01)
    assert 0 < fk.most_common_frac < 1


def test_stats_epoch_deterministic_and_sensitive(rng):
    a = rng.integers(0, 9, 500).astype(np.int32)
    db1 = Database().add(Multiset.from_columns("t", a=a))
    db2 = Database().add(Multiset.from_columns("t", a=a.copy()))
    assert db1.stats_epoch() == db2.stats_epoch()  # content-determined
    db3 = Database().add(Multiset.from_columns("t", a=np.concatenate([a, a[:3]])))
    assert db3.stats_epoch() != db1.stats_epoch()  # rows added → new epoch


# ---------------------------------------------------------------------------
# cardinality estimation vs. actual counts
# ---------------------------------------------------------------------------


def test_cardinality_range_filter_accuracy(db):
    d, k, v = db
    stats = collect_stats(d)
    p = sql_to_forelem("SELECT k FROM t WHERE v < 37", {"t": ["k", "v"]})
    est = CardinalityEstimator(stats)
    filtered = p.body[0].indexset
    got = est.indexset_rows(filtered, {})
    actual = int((v < 37).sum())
    assert got == pytest.approx(actual, rel=0.3)


def test_cardinality_equality_and_groupby(db):
    d, k, v = db
    stats = collect_stats(d)
    est = CardinalityEstimator(stats)
    p = sql_to_forelem("SELECT v FROM t WHERE k = 7", {"t": ["k", "v"]})
    got = est.indexset_rows(p.body[0].indexset, {})
    actual = int((k == 7).sum())
    # uniform keys: 1/n_distinct is a good estimate
    assert got == pytest.approx(actual, rel=0.5)
    assert est.groupby_output("t", "k") == len(np.unique(k))


def test_loop_estimates_propagate_through_nesting(db):
    d, k, v = db
    stats = collect_stats(d)
    p = sql_to_forelem("SELECT k, COUNT(k) FROM t GROUP BY k", {"t": ["k", "v"]})
    ests = CardinalityEstimator(stats).loop_estimates(p)
    assert len(ests) == 2  # scan loop + distinct loop
    assert ests[0].total == pytest.approx(4000)
    assert ests[1].total == pytest.approx(len(np.unique(k)))


# ---------------------------------------------------------------------------
# join-order enumeration (interchange hook)
# ---------------------------------------------------------------------------


def test_join_orders_preserve_semantics(rng):
    # duplicated fk side: IR-level interchange must preserve semantics
    # (checked on the reference interpreter, which handles duplicates)
    A = Multiset.from_columns("A", b_id=rng.integers(0, 30, 120).astype(np.int32),
                              f=rng.integers(0, 9, 120).astype(np.int32))
    B = Multiset.from_columns("B", id=np.arange(30).astype(np.int32),
                              g=rng.integers(0, 9, 30).astype(np.int32))
    d = Database().add(A).add(B)
    p = sql_to_forelem("SELECT a.f, b.g FROM A a, B b WHERE a.b_id = b.id",
                       {"A": ["b_id", "f"], "B": ["id", "g"]})
    variants = join_orders(p)
    assert len(variants) == 1
    ref = sorted(ReferenceInterpreter(d).run(p)["R"])
    for variant in variants:
        assert sorted(ReferenceInterpreter(d).run(variant)["R"]) == ref


def test_join_orders_jax_lowering_1to1(rng):
    # both keys unique (1:1 join): every orientation lowers and agrees
    A = Multiset.from_columns("A", b_id=rng.permutation(40).astype(np.int32),
                              f=rng.integers(0, 9, 40).astype(np.int32))
    B = Multiset.from_columns("B", id=np.arange(40).astype(np.int32),
                              g=rng.integers(0, 9, 40).astype(np.int32))
    d = Database().add(A).add(B)
    p = sql_to_forelem("SELECT a.f, b.g FROM A a, B b WHERE a.b_id = b.id",
                       {"A": ["b_id", "f"], "B": ["id", "g"]})
    ref = sorted(ReferenceInterpreter(d).run(p)["R"])
    assert sorted(Plan(p, d).run()["R"]) == ref
    for variant in join_orders(p):
        assert sorted(Plan(variant, d).run()["R"]) == ref


def test_join_duplicate_build_keys_expand(rng):
    # both sides duplicated (many-to-many): the expansion lowering must
    # produce every match pair, exactly like the reference interpreter
    A = Multiset.from_columns("A", b_id=rng.integers(0, 5, 50).astype(np.int32))
    B = Multiset.from_columns("B", id=rng.integers(0, 5, 50).astype(np.int32))
    d = Database().add(A).add(B)
    p = sql_to_forelem("SELECT a.b_id, b.id FROM A a, B b WHERE a.b_id = b.id",
                       {"A": ["b_id"], "B": ["id"]})
    got = sorted(Plan(p, d).run()["R"])
    assert got == sorted(ReferenceInterpreter(d).run(p)["R"])
    # forcing the unique-lookup lowering onto duplicate keys must refuse
    from repro.core.lower import UnsupportedProgram

    with pytest.raises(UnsupportedProgram):
        Plan(p, d, CodegenChoices(join_method="lookup"))


def test_planner_enumerates_join_orders(rng):
    # 1:1 join: both orientations are key-unique, so both are enumerated
    A = Multiset.from_columns("A", b_id=rng.permutation(200).astype(np.int32))
    B = Multiset.from_columns("B", id=np.arange(200).astype(np.int32))
    d = Database().add(A).add(B)
    p = sql_to_forelem("SELECT a.b_id, b.id FROM A a, B b WHERE a.b_id = b.id",
                       {"A": ["b_id"], "B": ["id"]})
    cands = enumerate_candidates(p, collect_stats(d))
    orders = {c.order for c in cands}
    assert "as-written" in orders and any(o.startswith("interchanged") for o in orders)


def test_planner_join_method_per_orientation(rng):
    # fk side duplicated: the as-written orientation (unique build) may use
    # the cheap lookup; the interchanged orientation (duplicate build keys)
    # must only be offered with the expansion lowering — and every
    # enumerated candidate must execute to the reference answer
    A = Multiset.from_columns("A", b_id=rng.integers(0, 30, 500).astype(np.int32),
                              f=rng.integers(0, 9, 500).astype(np.int32))
    B = Multiset.from_columns("B", id=np.arange(30).astype(np.int32),
                              g=rng.integers(0, 9, 30).astype(np.int32))
    d = Database().add(A).add(B)
    p = sql_to_forelem("SELECT a.f, b.g FROM A a, B b WHERE a.b_id = b.id",
                       {"A": ["b_id", "f"], "B": ["id", "g"]})
    decision = plan_query(p, collect_stats(d))
    pairs = {(c.order, c.join_method) for c in decision.candidates}
    assert ("as-written", "lookup") in pairs
    assert ("interchanged[0]", "expand") in pairs
    assert ("interchanged[0]", "lookup") not in pairs
    # the unique-build lookup orientation is the cheap one
    assert decision.chosen.join_method == "lookup"
    ref = sorted(ReferenceInterpreter(d).run(p)["R"])
    for c in decision.candidates:
        got = sorted(Plan(c.program, d, CodegenChoices(join_method=c.join_method)).run()["R"])
        assert got == ref


# ---------------------------------------------------------------------------
# cost-model ranking sanity
# ---------------------------------------------------------------------------


def _timed(plan: Plan, repeats: int = 3) -> float:
    cols = plan.input_columns()
    jax.block_until_ready(plan.fn(cols))  # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(plan.fn(cols))
        best = min(best, time.perf_counter() - t0)
    return best


def test_chosen_plan_not_slower_than_worst(rng):
    # many keys: the one-hot matmul (rows × keys work) is catastrophically
    # worse than dense scatter-add; the model must reflect that ordering
    k = rng.integers(0, 2000, 50_000).astype(np.int32)
    d = Database().add(Multiset.from_columns("t", k=k))
    p = sql_to_forelem("SELECT k, COUNT(k) FROM t GROUP BY k", {"t": ["k"]})
    decision = plan_query(p, collect_stats(d))
    chosen, worst = decision.candidates[0], decision.candidates[-1]
    assert chosen.cost <= worst.cost
    assert chosen.agg_method != "onehot"
    t_chosen = _timed(Plan(chosen.program, d, CodegenChoices(agg_method=chosen.agg_method)))
    t_worst = _timed(Plan(worst.program, d, CodegenChoices(agg_method=worst.agg_method)))
    assert t_chosen <= t_worst * 1.2


def test_planner_matches_fixed_defaults_results(db):
    d, k, v = db
    p = sql_to_forelem("SELECT k, COUNT(k), SUM(v) FROM t GROUP BY k", {"t": ["k", "v"]})
    fixed = optimize(p, d, OptimizeOptions(n_parts=4, planner="none"))
    planned = optimize(p, d, OptimizeOptions(n_parts=4, planner="cost", plan_cache=PlanCache()))
    assert sorted(planned.plan.run()["R"]) == sorted(fixed.plan.run()["R"])
    assert planned.decision is not None
    assert planned.decision.chosen.agg_method in ("dense", "sort", "onehot", "kernel")
    assert planned.explain and "EXPLAIN" in planned.explain


def test_unknown_planner_rejected(db):
    d, _, _ = db
    p = sql_to_forelem("SELECT k FROM t", {"t": ["k", "v"]})
    with pytest.raises(ValueError):
        optimize(p, d, OptimizeOptions(planner="bogus"))


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------


def test_plan_cache_hit_and_epoch_invalidation(rng):
    k = rng.integers(0, 12, 1000).astype(np.int32)
    d = Database().add(Multiset.from_columns("t", k=k))
    p = sql_to_forelem("SELECT k, COUNT(k) FROM t GROUP BY k", {"t": ["k"]})
    cache = PlanCache()
    opts = OptimizeOptions(planner="cost", plan_cache=cache)
    r1 = optimize(p, d, opts)
    assert not r1.cache_hit and cache.stats()["misses"] == 1
    r2 = optimize(p, d, opts)
    assert r2.cache_hit and cache.stats()["hits"] == 1
    assert sorted(r2.plan.run()["R"]) == sorted(r1.plan.run()["R"])
    # data change → stats epoch change → miss (and correct new results)
    d2 = Database().add(Multiset.from_columns("t", k=np.concatenate([k, k])))
    r3 = optimize(p, d2, opts)
    assert not r3.cache_hit
    assert dict(r3.plan.run()["R"]) == {kk: 2 * c for kk, c in r1.plan.run()["R"]}


def test_plan_cache_invalidates_on_midcolumn_edit():
    # head/tail-only fingerprints would collide here and serve stale results
    s1 = np.full(1000, 200, np.int32)
    s2 = s1.copy()
    s2[100:900] = 500
    db1 = Database().add(Multiset.from_columns("t", status=s1))
    db2 = Database().add(Multiset.from_columns("t", status=s2))
    assert db1.stats_epoch() != db2.stats_epoch()
    p = sql_to_forelem("SELECT status, COUNT(status) FROM t GROUP BY status", {"t": ["status"]})
    cache = PlanCache()
    optimize(p, db1, OptimizeOptions(planner="cost", plan_cache=cache))
    r2 = optimize(p, db2, OptimizeOptions(planner="cost", plan_cache=cache))
    assert not r2.cache_hit
    assert sorted(r2.plan.run()["R"]) == [(200, 200), (500, 800)]


def test_plan_cache_keyed_on_planning_inputs(rng):
    # a plan compiled for n_parts=1 must not satisfy an n_parts=8 request
    d = Database().add(Multiset.from_columns("t", k=rng.integers(0, 9, 500).astype(np.int32)))
    p = sql_to_forelem("SELECT k, COUNT(k) FROM t GROUP BY k", {"t": ["k"]})
    cache = PlanCache()
    optimize(p, d, OptimizeOptions(planner="cost", plan_cache=cache, n_parts=1))
    r = optimize(p, d, OptimizeOptions(planner="cost", plan_cache=cache, n_parts=8))
    assert not r.cache_hit


def test_dict_column_stats_exact_under_sampling():
    # 300k rows exceeds the stats sampling cap; the dictionary still gives
    # exact distinct counts and key-uniqueness
    from repro.data.multiset import dict_encode

    vals = np.array([f"u{i % 7}" for i in range(300_000)], dtype=object)
    d = Database().add(Multiset("t", {"k": dict_encode(vals)}))
    fs = collect_stats(d).field("t", "k")
    assert fs.n_distinct == 7
    assert fs.is_unique is False


def test_plan_cache_distinguishes_programs(db):
    d, _, _ = db
    p1 = sql_to_forelem("SELECT k, COUNT(k) FROM t GROUP BY k", {"t": ["k", "v"]})
    p2 = sql_to_forelem("SELECT k, SUM(v) FROM t GROUP BY k", {"t": ["k", "v"]})
    assert program_fingerprint(p1) != program_fingerprint(p2)
    p3 = sql_to_forelem("SELECT k, COUNT(k) FROM t GROUP BY k", {"t": ["k", "v"]})
    assert program_fingerprint(p1) == program_fingerprint(p3)


def test_plan_cache_lru_eviction():
    cache = PlanCache(capacity=2)
    from repro.planner.cache import CacheEntry

    for i in range(3):
        cache.put(f"fp{i}", "e", CacheEntry(None, None, "", None, "e"))
    assert len(cache) == 2
    assert cache.get("fp0", "e") is None  # evicted
    assert cache.get("fp2", "e") is not None


# ---------------------------------------------------------------------------
# EXPLAIN
# ---------------------------------------------------------------------------


def test_explain_shows_estimates_and_choices(db):
    d, k, v = db
    p = sql_to_forelem("SELECT k, COUNT(k) FROM t GROUP BY k", {"t": ["k", "v"]})
    decision = plan_query(p, collect_stats(d))
    text = render_explain(decision, name="q")
    assert "EXPLAIN q" in text
    assert "rows≈" in text and "est_cost≈" in text
    assert "agg_method=" in text and "rejected alternatives" in text


# ---------------------------------------------------------------------------
# ORDER BY / LIMIT (SQL frontend + lowering)
# ---------------------------------------------------------------------------


def test_order_by_limit_topk(db):
    d, k, v = db
    p = sql_to_forelem(
        "SELECT k, COUNT(k) AS c FROM t GROUP BY k ORDER BY c DESC LIMIT 3", {"t": ["k", "v"]}
    )
    got = Plan(p, d).run()["R"]
    vals, counts = np.unique(k, return_counts=True)
    want = sorted(zip(vals.tolist(), counts.tolist()), key=lambda r: -r[1])[:3]
    assert [c for _, c in got] == [c for _, c in want]
    # count column agrees with the reference (tie order among equal counts
    # is unspecified, so compare the ordered count column only)
    ref = ReferenceInterpreter(d).run(p)["R"]
    assert [c for _, c in ref] == [c for _, c in got]


def test_order_by_asc_on_projection(db):
    d, k, v = db
    p = sql_to_forelem("SELECT v FROM t WHERE k = 3 ORDER BY v ASC LIMIT 10", {"t": ["k", "v"]})
    got = [r[0] for r in Plan(p, d).run()["R"]]
    want = sorted(v[k == 3].tolist())[:10]
    assert got == want


def test_order_by_errors():
    with pytest.raises(SQLError):
        sql_to_forelem("SELECT k FROM t ORDER BY nope", {"t": ["k"]})
    with pytest.raises(SQLError):
        sql_to_forelem("SELECT SUM(k) FROM t LIMIT 2", {"t": ["k"]})

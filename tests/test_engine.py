# The unified query engine: Session front door, SQL↔MapReduce equivalence
# through one planner pipeline and one plan cache, executor-backend
# registry, export_mr round-trips, and the stats-epoch invalidation
# semantics on table replacement.
import numpy as np
import pytest

from repro import MapReduceSpec, Session
from repro.backends import available_backends, get_backend
from repro.core import OptimizeOptions, optimize
from repro.core.ir import Const, FieldRef
from repro.core.transforms import canonicalize_array_names
from repro.data.multiset import Database, Multiset
from repro.engine import EngineError
from repro.frontends.export_mr import NotMapReduceShape, forelem_to_mapreduce
from repro.frontends.mapreduce import mapreduce_to_forelem, run_python_mapreduce
from repro.frontends.sql import sql_to_forelem
from repro.planner import PlanCache, program_fingerprint


@pytest.fixture
def web_session(rng):
    urls = rng.integers(0, 17, 800).astype(np.int32)
    lat = rng.gamma(2.0, 30.0, 800).astype(np.float32)
    s = Session(n_parts=4)
    s.register("access", url=urls, latency=lat)
    return s, urls, lat


# ---------------------------------------------------------------------------
# SQL ↔ MapReduce equivalence through the Session
# ---------------------------------------------------------------------------


def test_sql_mapreduce_same_results_and_shared_plan_cache_entry(web_session):
    s, urls, _ = web_session
    r_sql = s.sql("SELECT url, COUNT(url) FROM access GROUP BY url")
    assert r_sql.cache_hit is False
    r_mr = s.mapreduce(MapReduceSpec.count("access", "url"))
    # identical logical query → identical results AND a plan-cache hit
    assert sorted(r_mr.rows) == sorted(r_sql.rows)
    assert r_mr.cache_hit is True
    assert len(s.plan_cache) == 1  # one shared entry, not two
    vals, counts = np.unique(urls, return_counts=True)
    assert sorted(r_sql.rows) == [(int(v), int(c)) for v, c in zip(vals, counts)]


def test_sql_mapreduce_sum_by_key_equivalence(web_session):
    s, urls, lat = web_session
    r_mr = s.mapreduce(MapReduceSpec.aggregate("access", "url", "latency", "+"))
    r_sql = s.sql("SELECT url, SUM(latency) FROM access GROUP BY url")
    assert r_sql.cache_hit is True  # MR came first; SQL reuses its plan
    a = {k: v for k, v in r_mr.rows}
    b = {k: v for k, v in r_sql.rows}
    assert set(a) == set(b)
    for k in a:
        assert a[k] == pytest.approx(b[k], rel=1e-5)


def test_canonicalized_fingerprints_match_across_frontends():
    sql_p = sql_to_forelem(
        "SELECT url, COUNT(url) FROM access GROUP BY url", {"access": ["url"]}
    )
    mr_p = mapreduce_to_forelem(MapReduceSpec("access", "url", Const(1)), ["url"])
    assert program_fingerprint(canonicalize_array_names(sql_p)) == program_fingerprint(
        canonicalize_array_names(mr_p)
    )
    # without canonicalization the internal array names differ
    assert program_fingerprint(sql_p) != program_fingerprint(mr_p)


def test_mapreduce_gets_planner_explain(web_session):
    s, _, _ = web_session
    text = s.explain(MapReduceSpec.count("access", "url"))
    assert "EXPLAIN" in text and "chosen:" in text
    assert "agg_method=" in text


def test_warm_dispatch_and_repeat_submission(web_session):
    s, _, _ = web_session
    q = "SELECT url, COUNT(url) FROM access GROUP BY url"
    r1 = s.sql(q)
    r2 = s.sql(q)
    assert r1.dispatch_hit is False
    assert r2.dispatch_hit is True and r2.cache_hit is True
    assert sorted(r1.rows) == sorted(r2.rows)


def test_mapreduce_params_and_reference_backend(rng):
    k = rng.integers(0, 5, 200).astype(np.int32)
    v = rng.integers(0, 50, 200).astype(np.int32)
    out = {}
    for backend in ("jax", "reference"):
        s = Session(backend=backend)
        s.register("t", k=k, v=v)
        out[backend] = sorted(s.mapreduce(MapReduceSpec.aggregate("t", "k", "v", "max")).rows)
    assert out["jax"] == out["reference"]


# ---------------------------------------------------------------------------
# Stats epoch / plan-cache invalidation on table replacement
# ---------------------------------------------------------------------------


def test_replacing_table_invalidates_old_epoch_plans():
    s = Session(n_parts=2)
    s.register("t", k=np.array([0, 1, 0, 1, 2], dtype=np.int32),
               v=np.arange(5, dtype=np.int32))
    r1 = s.sql("SELECT k, SUM(v) FROM t GROUP BY k")
    assert sorted(r1.rows) == [(0, 2), (1, 4), (2, 4)]
    assert len(s.plan_cache) == 1
    # replace with different content: the old compiled plan baked in a
    # key space of 3 — serving it against the new data would be wrong
    s.register("t", k=np.array([5, 5, 6], dtype=np.int32),
               v=np.array([10, 20, 30], dtype=np.int32))
    assert len(s.plan_cache) == 0  # invalidate_epoch dropped the stale entry
    r2 = s.sql("SELECT k, SUM(v) FROM t GROUP BY k")
    assert r2.cache_hit is False
    assert sorted(r2.rows) == [(5, 30), (6, 30)]


def test_identical_content_replacement_still_bumps_epoch():
    k = np.array([1, 1, 2], dtype=np.int32)
    s = Session()
    s.register("t", k=k)
    s.sql("SELECT k, COUNT(k) FROM t GROUP BY k")
    e0 = s.stats_epoch()
    s.register("t", k=k.copy())  # same bytes — content fingerprint agrees
    assert s.stats_epoch() != e0  # the explicit bump still forces a new epoch
    r = s.sql("SELECT k, COUNT(k) FROM t GROUP BY k")
    assert r.cache_hit is False and sorted(r.rows) == [(1, 2), (2, 1)]


def test_out_of_band_db_mutation_is_not_served_stale_plans():
    # Session.db is public and mutable; a table swapped in behind the
    # Session's back must still invalidate the warm-dispatch memo (the
    # epoch is revalidated per dispatch, not trusted from the last refresh)
    s = Session()
    s.register("t", k=np.array([0, 1, 0, 1, 2], dtype=np.int32),
               v=np.arange(5, dtype=np.int32))
    q = "SELECT k, SUM(v) FROM t GROUP BY k"
    assert sorted(s.sql(q).rows) == [(0, 2), (1, 4), (2, 4)]
    s.db.add(Multiset.from_columns("t", k=np.array([9, 9], dtype=np.int32),
                                   v=np.array([1, 2], dtype=np.int32)))
    r = s.sql(q)
    assert r.dispatch_hit is False
    assert sorted(r.rows) == [(9, 3)]


def test_in_place_column_edit_is_revalidated():
    # the default revalidate='content' catches buffer mutation that leaves
    # the table object (and its id/length) unchanged
    s = Session()
    s.register("t", k=np.array([0, 1, 0, 1, 2], dtype=np.int32),
               v=np.array([1, 1, 1, 1, 1], dtype=np.int32))
    q = "SELECT k, SUM(v) FROM t GROUP BY k"
    assert sorted(s.sql(q).rows) == [(0, 2), (1, 2), (2, 1)]
    s.db["t"].columns["k"].values[:] = np.array([7, 7, 7, 8, 8], dtype=np.int32)
    r = s.sql(q)
    assert r.dispatch_hit is False
    assert sorted(r.rows) == [(7, 3), (8, 2)]


def test_signature_revalidation_mode_catches_table_swap():
    s = Session(revalidate="signature")
    s.register("t", k=np.array([0, 1], dtype=np.int32), v=np.array([1, 2], dtype=np.int32))
    q = "SELECT k, SUM(v) FROM t GROUP BY k"
    assert sorted(s.sql(q).rows) == [(0, 1), (1, 2)]
    s.db.add(Multiset.from_columns("t", k=np.array([3], dtype=np.int32),
                                   v=np.array([9], dtype=np.int32)))
    assert sorted(s.sql(q).rows) == [(3, 9)]
    with pytest.raises(EngineError):
        Session(revalidate="bogus")


def test_history_is_metadata_only(web_session):
    s, _, _ = web_session
    s.sql("SELECT url, COUNT(url) FROM access GROUP BY url")
    entry = s.history[-1]
    assert entry.source == "sql" and entry.elapsed_s > 0
    assert not hasattr(entry, "results") and not hasattr(entry, "plan")


def test_schema_changing_replace_reparses_programs():
    # the frontend parse memo must not survive a schema change: the old
    # program binds columns that no longer exist
    s = Session()
    s.register("t", k=np.array([0, 1], dtype=np.int32), v=np.array([1, 2], dtype=np.int32))
    q_old = "SELECT k, SUM(v) FROM t GROUP BY k"
    assert sorted(s.sql(q_old).rows) == [(0, 1), (1, 2)]
    s.register("t", k=np.array([0, 1], dtype=np.int32), w=np.array([5, 6], dtype=np.int32))
    with pytest.raises(Exception):
        s.sql(q_old)  # column v is gone — must error, not run a stale plan
    assert sorted(s.sql("SELECT k, SUM(w) FROM t GROUP BY k").rows) == [(0, 5), (1, 6)]


def test_drop_table_invalidates(web_session):
    s, _, _ = web_session
    s.sql("SELECT url, COUNT(url) FROM access GROUP BY url")
    assert len(s.plan_cache) == 1
    s.drop("access")
    assert len(s.plan_cache) == 0
    assert "access" not in s.db
    with pytest.raises(EngineError):
        s.drop("access")


def test_register_rejects_bad_arguments():
    s = Session()
    with pytest.raises(EngineError):
        s.register("t")  # no columns
    with pytest.raises(EngineError):
        s.register(Multiset.from_columns("t", k=np.arange(3)), k=np.arange(3))
    with pytest.raises(EngineError):
        s.mapreduce(MapReduceSpec.count("missing", "k"))


# ---------------------------------------------------------------------------
# Backend registry and the core/lower.py compat shim
# ---------------------------------------------------------------------------


def test_backend_registry_names():
    assert {"jax", "reference"} <= set(available_backends())
    with pytest.raises(KeyError):
        get_backend("no-such-backend")


def test_backends_are_keyed_separately_in_plan_cache(rng):
    k = rng.integers(0, 4, 100).astype(np.int32)
    db = Database().add(Multiset.from_columns("t", k=k))
    cache = PlanCache()
    p = sql_to_forelem("SELECT k, COUNT(k) FROM t GROUP BY k", {"t": ["k"]})
    r_jax = optimize(p, db, OptimizeOptions(planner="cost", plan_cache=cache, backend="jax"))
    r_ref = optimize(p, db, OptimizeOptions(planner="cost", plan_cache=cache, backend="reference"))
    assert r_jax.cache_hit is False and r_ref.cache_hit is False
    assert len(cache) == 2  # one compiled plan per backend
    assert sorted(r_jax.plan.run()["R"]) == sorted(r_ref.plan.run()["R"])


def test_lower_shim_reexports():
    from repro.core import lower

    from repro.backends import jax_vec, reference, codegen

    assert lower.Plan is jax_vec.Plan
    assert lower.CodegenChoices is jax_vec.CodegenChoices
    assert lower.ReferenceInterpreter is reference.ReferenceInterpreter
    assert lower.extract_spec is codegen.extract_spec
    assert lower.UnsupportedProgram is codegen.UnsupportedProgram


# ---------------------------------------------------------------------------
# export_mr round trips (forelem → MapReduce → Hadoop-style execution)
# ---------------------------------------------------------------------------


def _run_exported(mr, ms):
    fields = ms.field_names()
    cols = {f: np.asarray(ms.field(f)) for f in fields}
    rows = (
        (i, {f: cols[f][i].item() for f in fields})
        for i in range(len(ms))
    )
    return sorted(run_python_mapreduce(mr.map_fn, mr.reduce_fn, rows, 4))


def test_export_mr_roundtrip_count(web_session):
    s, _, _ = web_session
    r = s.sql("SELECT url, COUNT(url) FROM access GROUP BY url")
    mr = forelem_to_mapreduce(
        sql_to_forelem("SELECT url, COUNT(url) FROM access GROUP BY url", s.schemas())
    )
    got = _run_exported(mr, s.db["access"])
    assert got == sorted(r.rows)
    assert "emitIntermediate" in mr.pseudocode


def test_export_mr_roundtrip_sum(rng):
    k = rng.integers(0, 6, 150).astype(np.int32)
    v = rng.integers(0, 30, 150).astype(np.int32)
    s = Session()
    s.register("t", k=k, v=v)
    spec = MapReduceSpec.aggregate("t", "k", "v", "+")
    r = s.mapreduce(spec)
    # engine → IR → exported MR program → Hadoop-style executor
    prog = mapreduce_to_forelem(spec, ["k", "v"])
    mr = forelem_to_mapreduce(prog)
    got = _run_exported(mr, s.db["t"])
    assert got == sorted(r.rows)


def test_export_mr_rejects_non_mr_shape():
    p = sql_to_forelem("SELECT k FROM t WHERE k > 1", {"t": ["k"]})
    with pytest.raises(NotMapReduceShape):
        forelem_to_mapreduce(p)


def test_export_mr_canonicalized_program_roundtrip():
    # canonicalization must not break the two-adjacent-loop shape detection
    prog = canonicalize_array_names(
        mapreduce_to_forelem(MapReduceSpec("t", "k", FieldRef("t", "i", "v")), ["k", "v"])
    )
    mr = forelem_to_mapreduce(prog)
    assert mr.table == "t"


# ---------------------------------------------------------------------------
# Results surface
# ---------------------------------------------------------------------------


def test_scalar_and_ordered_results(web_session):
    s, urls, lat = web_session
    r = s.sql("SELECT SUM(latency) FROM access WHERE url = 3")
    assert r.scalar() == pytest.approx(float(lat[urls == 3].sum()), rel=1e-4)
    top = s.sql("SELECT url, COUNT(url) AS c FROM access GROUP BY url ORDER BY c DESC LIMIT 3")
    counts = sorted(np.unique(urls, return_counts=True)[1], reverse=True)[:3]
    assert [c for _, c in top.rows] == [int(c) for c in counts]

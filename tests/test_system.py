# End-to-end behaviour tests for the paper's system: data → IR → optimize →
# execute → train, plus the launch-layer sharding logic (pure parts — the
# 512-device lowering itself runs in launch/dryrun.py, not under pytest).
import dataclasses
from types import SimpleNamespace

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, get_config, list_archs, reduced_config, valid_cells


def test_full_bigdata_session():
    """SQL session over weblogs: optimize (reformat+parallelize) and check
    answers against numpy on the raw strings."""
    from repro.core import OptimizeOptions, optimize
    from repro.data.multiset import Database, Multiset, PlainColumn
    from repro.frontends.sql import sql_to_forelem

    rng = np.random.default_rng(0)
    n = 20_000
    urls = np.array([f"http://s{u%31}.com" for u in rng.zipf(1.5, n) % 500], dtype=object)
    status = rng.choice([200, 404, 500], n).astype(np.int32)
    db = Database().add(Multiset("logs", {"url": PlainColumn(urls), "status": PlainColumn(status)}))
    schemas = {"logs": ["url", "status"]}

    res = optimize(sql_to_forelem("SELECT status, COUNT(status) FROM logs GROUP BY status", schemas),
                   db, OptimizeOptions(n_parts=4))
    got = dict(res.plan.run()["R"])
    vals, counts = np.unique(status, return_counts=True)
    assert got == {int(v): int(c) for v, c in zip(vals, counts)}

    res2 = optimize(sql_to_forelem("SELECT SUM(status) FROM logs WHERE status = 500", schemas),
                    res.db, OptimizeOptions(n_parts=1, reformat=False))
    assert res2.plan.run()["scalar"] == int(status[status == 500].sum())


def test_pipeline_to_training_loss_drops():
    """The paper's vertical integration, LM edition: forelem data pipeline
    feeds the training loop; loss decreases."""
    from repro.data.pipeline import PipelineConfig, ShardedLoader, build_dataset
    from repro.models.transformer import Model
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.step import TrainSpec, make_train_step

    rng = np.random.default_rng(0)
    docs = []
    for _ in range(200):
        state = int(rng.integers(0, 64))
        words = []
        for _ in range(int(rng.integers(20, 100))):
            state = (state * 7 + 3) % 64
            words.append(f"tok{state}")
        docs.append(" ".join(words))
    ds = build_dataset(docs, PipelineConfig(seq_len=32, min_doc_tokens=8, vocab_size=128))
    cfg = dataclasses.replace(
        reduced_config(get_config("starcoder2-3b")), n_layers=2, d_model=64,
        vocab_size=ds.vocab.size, window=32, max_seq_len=32)
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(m, AdamWConfig(lr_peak=5e-3, warmup_steps=5, total_steps=30),
                                   TrainSpec(microbatches=2, remat=False)))
    loader = ShardedLoader(ds, global_batch=8)
    losses = []
    for s in range(15):
        batch = {k: jnp.asarray(v) for k, v in loader.batch(s).items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_checkpoint_restart_resumes_exactly(tmp_path):
    """Fault-tolerance: kill-and-restore reproduces the same parameters."""
    from repro.models.transformer import Model
    from repro.train.checkpoint import CheckpointManager
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.step import TrainSpec, make_train_step

    cfg = dataclasses.replace(reduced_config(get_config("starcoder2-3b")), n_layers=2, vocab_size=64)
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(m, AdamWConfig(), TrainSpec(microbatches=1, remat=False)))
    batch = {"tokens": jnp.asarray(np.random.default_rng(0).integers(0, 64, (4, 16)), jnp.int32)}

    mgr = CheckpointManager(str(tmp_path))
    for s in range(3):
        params, opt, _ = step(params, opt, batch)
    mgr.save(3, (params, opt))
    p4, o4, _ = step(params, opt, batch)  # step 4 on the survivor

    _, (rp, ro) = mgr.restore((params, opt))  # failed node restarts
    rp4, ro4, _ = step(rp, ro, batch)
    for a, b in zip(jax.tree.leaves(p4), jax.tree.leaves(rp4)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# launch-layer sharding logic (pure — no 512-device init under pytest)
# ---------------------------------------------------------------------------


def _fake_mesh(**axes):
    return SimpleNamespace(shape=dict(axes))


def test_spec_from_axes_divisibility_fallback():
    from repro.launch.sharding import spec_from_axes

    mesh = _fake_mesh(data=16, model=16)
    rules = {"kv_heads": ["model"], "head_dim": ["model"], "batch": ["data"]}
    # kv_heads=8 does not divide 16 -> falls through; head_dim=256 divides
    spec = spec_from_axes(("batch", "kv_seq", "kv_heads", "head_dim"),
                          (128, 32768, 8, 256), rules, mesh)
    assert spec == jax.sharding.PartitionSpec("data", None, None, "model")


def test_spec_from_axes_no_axis_reuse():
    from repro.launch.sharding import spec_from_axes

    mesh = _fake_mesh(data=16, model=16)
    rules = {"a": ["model"], "b": ["model"]}
    spec = spec_from_axes(("a", "b"), (1600, 1600), rules, mesh)
    assert spec == jax.sharding.PartitionSpec("model")  # b can't reuse model


def test_spec_from_axes_replicates_small_tensors():
    from repro.launch.sharding import spec_from_axes

    mesh = _fake_mesh(data=16, model=16)
    spec = spec_from_axes(("embed",), (3584,), {"embed": ["data"]}, mesh)
    assert spec == jax.sharding.PartitionSpec()


def test_spec_from_axes_multi_axis_batch():
    from repro.launch.sharding import spec_from_axes

    mesh = _fake_mesh(pod=2, data=16, model=16)
    rules = {"batch": [("pod", "data")], "seq": []}
    spec = spec_from_axes(("batch", "seq"), (256, 4096), rules, mesh)
    assert spec == jax.sharding.PartitionSpec(("pod", "data"))


def test_input_specs_cover_all_cells():
    from repro.launch.specs import decode_cache_specs, input_specs

    for arch in list_archs():
        cfg = get_config(arch)
        for cell_name in valid_cells(cfg):
            cell = SHAPES[cell_name]
            specs = input_specs(cfg, cell)
            assert specs, (arch, cell_name)
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)
            if cell.kind == "decode":
                cache = decode_cache_specs(cfg, cell)
                assert jax.tree.leaves(cache), (arch, cell_name)


def test_cache_axes_congruent_with_cache_abstract():
    from repro.models.transformer import cache_abstract, cache_axes

    for arch in ("gemma2-9b", "rwkv6-3b", "zamba2-7b", "qwen2-vl-72b"):
        cfg = get_config(arch)
        ca = cache_abstract(cfg, 4, 128)
        ax = cache_axes(cfg)

        def check(sd, a):
            assert len(a) == len(sd.shape), (arch, sd.shape, a)

        jax.tree.map(check, ca, ax)


def test_mesh_helpers():
    from repro.launch.mesh import dp_axes, dp_size, make_smoke_mesh

    mesh = make_smoke_mesh()
    assert dp_axes(mesh) == ("data",)
    assert dp_size(mesh) == 1

# repro.analysis: the IR verifier's corruption matrix (every invariant
# violated once, with wrong-pass attribution), the dependence/legality layer
# gating the planner and the fixed pipeline, the plan linter, and a property
# check that random pass pipelines stay verifier-clean while agreeing with
# the reference interpreter.
import numpy as np
import pytest

from repro.analysis import (
    IRVerificationError,
    deps,
    lint_program,
    verify_enabled,
    verify_program,
)
from repro.backends import extract_spec, get_backend
from repro.backends.codegen import required_columns
from repro.core import transforms as T
from repro.core.ir import (
    Accumulate,
    ArrayRead,
    BinOp,
    Blocked,
    CombinePartials,
    Const,
    Distinct,
    FieldRef,
    Filtered,
    Forall,
    Forelem,
    FullSet,
    MultisetDecl,
    Program,
    ResultAppend,
    ScalarAssign,
    TupleExpr,
    TupleSchema,
    Var,
)
from repro.core.partition import partition_indirect
from repro.core.passes import OptimizeOptions, optimize
from repro.data.multiset import Database, Multiset
from repro.planner import collect_stats
from repro.planner.enumerate import plan_query

SCHEMA = TupleSchema((("k", "int32"), ("v", "int32"), ("s", "object")))
DECL = MultisetDecl("T", SCHEMA)


def groupby(op="+", tables=(DECL,), results=("R",)):
    """A well-formed group-by program over T(k, v, s)."""
    return Program(
        tables=tables,
        body=(
            Forelem(
                "i", FullSet("T"), (Accumulate("acc", FieldRef("T", "i", "k"), FieldRef("T", "i", "v"), op),)
            ),
            Forelem(
                "i",
                Distinct("T", "k"),
                (
                    ResultAppend(
                        "R",
                        TupleExpr((FieldRef("T", "i", "k"), ArrayRead("acc", FieldRef("T", "i", "k")))),
                    ),
                ),
            ),
        ),
        results=results,
        name="gb",
    )


def make_db(rng, n=200, nk=13):
    return Database().add(
        Multiset.from_columns(
            "T",
            k=rng.integers(0, nk, n).astype(np.int32),
            v=rng.integers(0, 50, n).astype(np.int32),
            s=np.array([f"s{i % 3}" for i in range(n)], dtype=object),
        )
    )


def run_ref(p, db):
    out = get_backend("reference").compile(p, db, None).run()
    return {k: sorted(v) if isinstance(v, list) else v for k, v in out.items()}


# ---------------------------------------------------------------------------
# Verifier: the happy path
# ---------------------------------------------------------------------------


def test_valid_program_verifies():
    assert verify_program(groupby()) is not None


def test_valid_privatized_program_verifies():
    p = partition_indirect(groupby(), "T", "k", 4)
    p = T.iteration_space_expansion(p)
    verify_program(p, pass_name="iteration_space_expansion")


def test_verify_enabled_parses_env(monkeypatch):
    for raw, want in [("1", True), ("0", False), ("false", False), ("on", True), ("", False)]:
        monkeypatch.setenv("REPRO_VERIFY_IR", raw)
        assert verify_enabled() is want
    monkeypatch.delenv("REPRO_VERIFY_IR")
    assert verify_enabled() is False
    assert verify_enabled(default=True) is True


# ---------------------------------------------------------------------------
# Verifier: the corruption matrix — every invariant violated exactly once
# ---------------------------------------------------------------------------


def _private_sum():
    """forall p: privatized accumulate over a blocked set (+ CombinePartials)."""
    acc = Accumulate("acc", FieldRef("T", "i", "k"), FieldRef("T", "i", "v"), "+", partitioned="p")
    loop = Forelem("i", Blocked(FullSet("T"), 4, "p"), (acc,))
    return Forall("p", 4, (loop,)), acc


CORRUPTIONS = {
    "duplicate-table": lambda: groupby(tables=(DECL, DECL)),
    "table-undeclared": lambda: Program(
        (DECL,), (Forelem("i", FullSet("U"), (ScalarAssign("x", Const(1)),)),), ("x",), name="bad"
    ),
    "field-missing": lambda: Program(
        (DECL,),
        (Forelem("i", FullSet("T"), (ScalarAssign("x", FieldRef("T", "i", "zz"), "+"),)),),
        ("x",),
        name="bad",
    ),
    "fieldref-scope": lambda: Program(
        (DECL,),
        (Forelem("i", FullSet("T"), (ScalarAssign("x", FieldRef("T", "j", "v"), "+"),)),),
        ("x",),
        name="bad",
    ),
    "var-unbound": lambda: Program(
        (DECL,), (ResultAppend("R", TupleExpr((Var("ghost"),))),), ("R",), name="bad"
    ),
    "array-undefined": lambda: Program(
        (DECL,), (ResultAppend("R", TupleExpr((ArrayRead("ghost", Const(0)),))),), ("R",), name="bad"
    ),
    "read-before-combine": lambda: Program(
        (DECL,),
        (_private_sum()[0], ResultAppend("R", TupleExpr((ArrayRead("acc", Const(0)),)))),
        ("R",),
        name="bad",
    ),
    "partvar-unbound": lambda: Program(
        (DECL,),
        (
            Forelem(
                "i",
                FullSet("T"),
                (Accumulate("acc", FieldRef("T", "i", "k"), Const(1), "+", partitioned="p"),),
            ),
        ),
        (),
        name="bad",
    ),
    "partition-mismatch": lambda: Program(
        (DECL,),
        (
            Forall(
                "p",
                4,
                (
                    Forelem(
                        "i",
                        Blocked(FullSet("T"), 2, "p"),
                        (Accumulate("acc", FieldRef("T", "i", "k"), Const(1), "+"),),
                    ),
                ),
            ),
        ),
        (),
        name="bad",
    ),
    "combine-mismatch": lambda: Program(
        (DECL,),
        (_private_sum()[0], CombinePartials("acc", "p", 4, "max")),
        (),
        name="bad",
    ),
    "nparts-invalid": lambda: Program(
        (DECL,), (Forall("p", 0, (ScalarAssign("x", Const(1)),)),), ("x",), name="bad"
    ),
    "op-invalid": lambda: groupby(op="weird"),
    "accumulate-op-conflict": lambda: Program(
        (DECL,),
        (
            Forelem(
                "i",
                FullSet("T"),
                (
                    Accumulate("acc", FieldRef("T", "i", "k"), Const(1), "+"),
                    Accumulate("acc", FieldRef("T", "i", "k"), Const(1), "max"),
                ),
            ),
        ),
        (),
        name="bad",
    ),
    "predicate-not-bool": lambda: Program(
        (DECL,),
        (
            Forelem(
                "i",
                Filtered("T", FieldRef("T", "_", "v"), FullSet("T")),
                (ScalarAssign("x", Const(1), "+"),),
            ),
        ),
        ("x",),
        name="bad",
    ),
    "type-mismatch": lambda: Program(
        (DECL,),
        (ScalarAssign("x", BinOp("+", Const("a"), Const(1))),),
        ("x",),
        name="bad",
    ),
    "result-unproduced": lambda: groupby(results=("R", "ghost")),
}


@pytest.mark.parametrize("invariant", sorted(CORRUPTIONS))
def test_corruption_is_caught(invariant):
    with pytest.raises(IRVerificationError) as ei:
        verify_program(CORRUPTIONS[invariant](), pass_name="loop_fusion")
    err = ei.value
    assert err.invariant == invariant
    assert err.pass_name == "loop_fusion"
    assert "after pass 'loop_fusion'" in str(err)
    assert invariant in str(err)


def test_optimize_attributes_corruption_to_offending_pass(monkeypatch, rng):
    """A transform that corrupts the IR is caught at *its* pass boundary."""
    db = make_db(rng)
    bad = CORRUPTIONS["field-missing"]()
    monkeypatch.setattr("repro.core.transforms.loop_fusion", lambda p, **kw: bad)
    with pytest.raises(IRVerificationError) as ei:
        optimize(
            groupby(),
            db,
            OptimizeOptions(planner="none", backend="reference", reformat=False, verify_ir=True),
        )
    assert ei.value.pass_name == "loop_fusion"
    assert ei.value.invariant == "field-missing"
    # the clean passes upstream of the corruption are NOT blamed
    assert ei.value.pass_name not in ("frontend", "loop_interchange", "dead_code_elimination")


def test_optimize_verify_off_does_not_check(monkeypatch, rng):
    db = make_db(rng)
    bad = groupby(results=("R", "ghost"))  # compiles fine; verifier would reject
    monkeypatch.setattr("repro.core.transforms.loop_fusion", lambda p, **kw: bad)
    optimize(
        groupby(),
        db,
        OptimizeOptions(planner="none", backend="reference", reformat=False, verify_ir=False),
    )


def test_optimize_verifies_frontend_input(rng):
    db = make_db(rng)
    with pytest.raises(IRVerificationError) as ei:
        optimize(
            CORRUPTIONS["table-undeclared"](),
            db,
            OptimizeOptions(planner="none", backend="reference", reformat=False, verify_ir=True),
        )
    assert ei.value.pass_name == "frontend"


# ---------------------------------------------------------------------------
# Dependence / legality (analysis.deps)
# ---------------------------------------------------------------------------


def test_op_algebra_classification():
    assert deps.is_mergeable("+") and deps.is_mergeable("max") and deps.is_mergeable("min")
    assert not deps.is_mergeable("first")          # associative, NOT commutative
    assert not deps.is_mergeable("no-such-op")     # unknown ops fail closed
    assert deps.merge_illegal_ops({"+", "max"}) == []
    assert deps.merge_illegal_ops({"+", "first"}) == ["first"]
    assert deps.merge_illegal_ops({"weird"}) == ["weird"]


def test_partitionable_proof():
    ok, reasons = deps.partitionable(groupby("+"))
    assert ok and reasons == []
    ok, reasons = deps.partitionable(groupby("first"))
    assert not ok
    assert any("first" in r for r in reasons)


def test_independent_fails_closed_on_unknown_stmt():
    class Mystery(ScalarAssign):
        pass

    a = ScalarAssign("x", Const(1))
    b = Mystery("y", Const(2))
    assert deps.independent(a, ScalarAssign("y", Const(2)))
    assert not deps.independent(a, b)
    assert deps.unknown_stmts(b)


def test_transforms_delegate_to_deps():
    p = groupby()
    s = p.body[0].body[0]
    assert T.stmt_reads(s) == deps.stmt_reads(s)
    assert T.stmt_writes(s) == deps.stmt_writes(s) == {"acc"}


def test_required_columns_matches_required_fields():
    p = groupby()
    spec = extract_spec(p)
    assert required_columns(p, spec) == deps.required_fields(p, spec)
    assert required_columns(p, spec)["T"] == {"k", "v"}


# ---------------------------------------------------------------------------
# Planner legality gate
# ---------------------------------------------------------------------------


def test_planner_rejects_noncommutative_partitioned(rng):
    db = make_db(rng)
    stats = collect_stats(db)
    d = plan_query(groupby("first"), stats, n_parts=8, executor="partitioned")
    assert d.chosen.n_partitions == 1
    assert all(c.n_partitions == 1 for c in d.candidates)
    assert d.rejections and "commutative" in d.rejections[0]


def test_planner_rejects_noncommutative_parallel(rng):
    db = make_db(rng)
    stats = collect_stats(db)
    d = plan_query(groupby("first"), stats, n_parts=8)
    assert d.chosen.parallel == "none"
    assert all(c.parallel == "none" for c in d.candidates)
    assert d.rejections


def test_planner_admits_mergeable_ops(rng):
    db = make_db(rng)
    stats = collect_stats(db)
    d = plan_query(groupby("+"), stats, n_parts=8, executor="partitioned")
    assert any((c.n_partitions or 1) > 1 for c in d.candidates)
    assert d.rejections == ()


def test_rejections_surface_in_explain(rng):
    from repro.planner import render_explain

    db = make_db(rng)
    d = plan_query(groupby("first"), collect_stats(db), n_parts=8, executor="partitioned")
    text = render_explain(d, "firstq")
    assert "legality (dependence analysis)" in text
    assert "commutative" in text


def test_fixed_pipeline_skips_illegal_parallelization(rng):
    db = make_db(rng)
    res = optimize(
        groupby("first"),
        db,
        OptimizeOptions(planner="none", backend="reference", n_parts=4, reformat=False, trace=True),
    )
    assert not any(isinstance(s, Forall) for s in res.program.body)
    assert any("skipped (illegal)" in t for t in res.trace)
    # and the sequential result is still the keep-first semantics
    out = res.plan.run()
    first = {}
    ks = db["T"].field("k")
    vs = db["T"].field("v")
    for k, v in zip(ks, vs):
        first.setdefault(int(k), int(v))
    assert sorted(out["R"]) == sorted(first.items())


def test_reference_first_op_keeps_first_value(rng):
    db = make_db(rng)
    out = run_ref(groupby("first"), db)
    first = {}
    for k, v in zip(db["T"].field("k"), db["T"].field("v")):
        first.setdefault(int(k), int(v))
    assert out["R"] == sorted(first.items())


# ---------------------------------------------------------------------------
# Lint
# ---------------------------------------------------------------------------


def test_lint_unused_and_skew_and_overflow():
    db = Database().add(
        Multiset.from_columns(
            "T",
            k=np.array([0, 0, 0, 0, 1], dtype=np.int32),
            v=np.array([100, 100, 100, 100, 5], dtype=np.int8),
            s=np.array(["a"] * 5, dtype=object),
        )
    )
    warnings = lint_program(groupby(), db=db, stats=collect_stats(db), n_partitions=8)
    rules = {w.rule for w in warnings}
    assert "unused-column" in rules     # 's' is never read
    assert "partition-skew" in rules    # 2 distinct keys for 8 partitions
    assert "sum-overflow" in rules      # 5 * 100 > int8 max
    assert all(str(w).startswith("[") for w in warnings)


def test_lint_clean_program():
    rng = np.random.default_rng(0)
    db = Database().add(
        Multiset.from_columns(
            "T",
            k=rng.integers(0, 64, 500).astype(np.int64),
            v=rng.integers(0, 50, 500).astype(np.int64),
        )
    )
    p = Program(
        tables=(db["T"].decl(),),
        body=groupby().body,
        results=("R",),
        name="gb",
    )
    assert lint_program(p, db=db, stats=collect_stats(db), n_partitions=4) == []


def test_lint_filter_pushdown():
    decl2 = MultisetDecl("U", TupleSchema((("k", "int32"),)))
    inner = Forelem(
        "j",
        Filtered("U", BinOp("<", FieldRef("U", "_", "k"), Const(3)), FullSet("U")),
        (ScalarAssign("x", Const(1), "+"),),
    )
    p = Program(
        tables=(DECL, decl2),
        body=(Forelem("i", FullSet("T"), (inner,)),),
        results=("x",),
        name="nested",
    )
    verify_program(p)
    warnings = lint_program(p)
    assert any(w.rule == "filter-pushdown" for w in warnings)


def test_session_check_and_explain_lint():
    from repro.engine import Session

    s = Session(n_parts=4, backend="partitioned", n_partitions=4)
    s.register(
        "access",
        url=np.array(["a", "a", "a", "a", "b"], dtype=object),
        size=np.array([100, 100, 100, 100, 5], dtype=np.int8),
        extra=np.arange(5),
    )
    rep = s.check("SELECT url, SUM(size) FROM access GROUP BY url")
    assert rep.ok and rep.error is None
    rules = {w.rule for w in rep.warnings}
    assert {"unused-column", "partition-skew", "sum-overflow"} <= rules
    assert "[partition-skew]" in str(rep)
    text = s.explain("SELECT url, SUM(size) FROM access GROUP BY url", lint=True)
    assert "lint:" in text and "[sum-overflow]" in text


# ---------------------------------------------------------------------------
# Property: random pass pipelines stay verifier-clean and agree with the
# reference interpreter
# ---------------------------------------------------------------------------

PIPELINE_PASSES = [
    ("loop_interchange", T.loop_interchange),
    ("dead_code_elimination", T.dead_code_elimination),
    ("loop_fusion", T.loop_fusion),
    (
        "partition_indirect+ise",
        lambda p: T.iteration_space_expansion(partition_indirect(p, "T", "k", 4)),
    ),
]


def _run_property(seed, n, nk, pass_idxs, op="+"):
    rng = np.random.default_rng(seed)
    db = make_db(rng, n=n, nk=nk)
    p = groupby(op)
    expected = run_ref(p, db)
    verify_program(p, pass_name="frontend")
    for i in pass_idxs:
        name, fn = PIPELINE_PASSES[i]
        p = fn(p)
        verify_program(p, pass_name=name)
    assert run_ref(p, db) == expected


@pytest.mark.parametrize(
    "seed,pass_idxs",
    [(0, [0, 1, 2]), (1, [3, 2]), (2, [2, 3]), (3, [0, 3, 2, 1]), (4, [1, 1, 2, 2])],
)
def test_pipelines_verifier_clean_deterministic(seed, pass_idxs):
    _run_property(seed, n=150, nk=11, pass_idxs=pass_idxs)


def test_property_random_pipelines():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        n=st.integers(5, 300),
        nk=st.integers(1, 30),
        pass_idxs=st.lists(st.integers(0, len(PIPELINE_PASSES) - 1), max_size=5),
        op=st.sampled_from(["+", "max", "min"]),
    )
    def prop(seed, n, nk, pass_idxs, op):
        # partitioning twice would nest foralls — dedup the composite pass
        if pass_idxs.count(3) > 1:
            pass_idxs = [i for i in pass_idxs if i != 3] + [3]
        _run_property(seed, n, nk, pass_idxs, op=op)

    prop()

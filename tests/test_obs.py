# Observability subsystem (repro.obs): span nesting/parentage — including
# cross-thread attachment under the async worker pool — metrics snapshot
# stability, Chrome-trace JSON schema validity, the trace ↔ dispatch_log
# agreement the acceptance criteria require, the bounded query log, and the
# well-formed empty runtime report.
import importlib.util
import json
import os
import sys
import threading

import numpy as np
import pytest

from repro import MetricsRegistry, Session, Tracer
from repro.backends import PartitionedChoices, get_backend
from repro.data.multiset import Database, Multiset
from repro.engine import EngineError
from repro.frontends.sql import sql_to_forelem
from repro.obs import NULL_TRACER, QueryTrace, diff_counters, load_trace
from repro.planner import PlanCache, render_analyze

SCHEMAS = {"t": ["k", "v"]}
Q = "SELECT k, SUM(v) FROM t GROUP BY k"


def _cols(n=20_000, key_range=40, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "k": rng.integers(0, key_range, n).astype(np.int32),
        "v": rng.integers(-1000, 1000, n).astype(np.int32),
    }


def _db(n=20_000, seed=0):
    return Database().add(Multiset.from_columns("t", **_cols(n, seed=seed)))


def _session(**kw):
    kw.setdefault("backend", "partitioned")
    kw.setdefault("n_partitions", 5)
    kw.setdefault("schedule", "guided")
    s = Session(**kw)
    s.register("t", **_cols())
    return s


# ---------------------------------------------------------------------------
# span tree: pipeline coverage + nesting
# ---------------------------------------------------------------------------


def test_profile_covers_every_pipeline_stage():
    s = _session()
    with s.profile() as qt:
        s.sql(Q)
    names = {sp.name for sp in qt.spans}
    for stage in ("query", "sql.parse", "canonicalize", "optimize", "passes",
                  "cache.lookup", "plan.enumerate", "lower", "distribute",
                  "execute", "dispatch"):
        assert stage in names, f"missing {stage} span; got {sorted(names)}"
    # one root: the query span; every other span reaches it via parents
    roots = qt.roots()
    assert [r.name for r in roots] == ["query"]
    for sp in qt.spans:
        if sp is roots[0]:
            continue
        chain = qt.ancestors(sp)
        assert chain and chain[-1] is roots[0], f"{sp.name} does not reach the query root"
    # per-chunk spans attach under the per-op dispatch span, not the root
    for d in qt.by_name("dispatch"):
        parent = qt.find(d.parent)
        assert parent is not None and parent.name.startswith("dispatch:")


def test_cache_lookup_span_records_hit_and_miss():
    shared = PlanCache()
    s1 = _session(plan_cache=shared, trace=True)
    s1.sql(Q)
    miss = [sp for sp in s1.take_trace().spans if sp.name == "cache.lookup"]
    assert miss and miss[0].attrs["hit"] is False
    # same arrays → same content epoch → the second session's lookup hits
    s2 = _session(plan_cache=shared, trace=True)
    s2.sql(Q)
    hit = [sp for sp in s2.take_trace().spans if sp.name == "cache.lookup"]
    assert hit and hit[0].attrs["hit"] is True


def test_trace_disabled_by_default_zero_spans_identical_results():
    plain = _session()
    traced = _session(trace=True)
    assert plain.tracer is NULL_TRACER
    r_plain = plain.sql(Q).rows
    r_traced = traced.sql(Q).rows
    assert sorted(r_plain) == sorted(r_traced)
    assert len(plain.take_trace()) == 0
    assert len(traced.take_trace()) > 0


# ---------------------------------------------------------------------------
# async worker pool: cross-thread parentage
# ---------------------------------------------------------------------------


def _pool_plan(db, n_partitions=4):
    p = sql_to_forelem(Q, SCHEMAS)
    return get_backend("partitioned").compile(
        p, db,
        PartitionedChoices(n_partitions=n_partitions, schedule="fixed",
                           jit_chunks=True, async_dispatch=True, n_workers=3),
    )


def test_async_chunk_spans_attach_to_owning_op():
    plan = _pool_plan(_db())
    tr = Tracer()
    plan.run(tracer=tr)
    qt = QueryTrace(tr.drain())
    chunks = qt.by_name("dispatch")
    assert len(chunks) == len(plan.dispatch_log) > 1
    ops = {sp.id: sp for sp in qt.spans if sp.name.startswith("dispatch:")}
    for c in chunks:
        # pool threads have no span stack to inherit from: the explicit
        # parent id must point at the op span whose name carries the op
        op = ops.get(c.parent)
        assert op is not None and op.name == f"dispatch:{c.attrs['op']}"
        assert c.attrs["worker"] in (0, 1, 2)


def test_concurrent_queries_keep_chunk_spans_on_their_own_query():
    tr = Tracer()
    plans = {tag: _pool_plan(_db(seed=i), n_partitions=4 + i)
             for i, tag in enumerate(("A", "B"))}

    def run(tag):
        with tr.span("query", q=tag):
            plans[tag].run(tracer=tr)

    threads = [threading.Thread(target=run, args=(tag,)) for tag in plans]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    qt = QueryTrace(tr.drain())
    per_query = {}
    for c in qt.by_name("dispatch"):
        qroots = [a for a in qt.ancestors(c) if a.name == "query"]
        assert len(qroots) == 1, "chunk span must reach exactly one query root"
        per_query.setdefault(qroots[0].attrs["q"], []).append(c)
    # every chunk landed under the query that dispatched it — counts match
    # each plan's own dispatch log exactly
    assert set(per_query) == {"A", "B"}
    for tag, plan in plans.items():
        assert len(per_query[tag]) == len(plan.dispatch_log)


# ---------------------------------------------------------------------------
# trace ↔ dispatch_log agreement
# ---------------------------------------------------------------------------


def test_dispatch_spans_agree_with_dispatch_log():
    s = _session()
    with s.profile() as qt:
        res = s.sql(Q)
    log = res.plan.dispatch_log
    recs = qt.dispatch_records()
    key = lambda d: (d["op"], d["partition"], d["rows"], d["worker"],
                     d["bucket"], d["compiled"])  # noqa: E731
    log_keys = sorted(key(d.trace_attrs()) for d in log)
    rec_keys = sorted(key(r) for r in recs)
    assert log_keys == rec_keys


def test_report_from_trace_matches_runtime_report():
    s = _session()
    with s.profile() as qt:
        res = s.sql(Q)
    from_log = res.plan.runtime_report()
    from_trace = res.plan.report_from_trace(qt)
    assert from_trace["ran"] and from_log["ran"]
    assert from_trace["n_dispatches"] == from_log["n_dispatches"]
    ops_l = {o["op"]: o for o in from_log["ops"]}
    ops_t = {o["op"]: o for o in from_trace["ops"]}
    assert set(ops_l) == set(ops_t)
    for op in ops_l:
        assert ops_t[op]["n_chunks"] == ops_l[op]["n_chunks"]
        assert ops_t[op]["rows"] == ops_l[op]["rows"]
        assert ops_t[op]["t_ms"] == pytest.approx(ops_l[op]["t_ms"])


def test_explain_analyze_renders_from_trace():
    s = _session()
    txt = s.explain(Q, analyze=True)
    assert "analyze (measured):" in txt
    assert "achieved_imbalance" in txt
    assert "jit cache:" in txt


# ---------------------------------------------------------------------------
# empty runtime report (regression: built-but-never-run / 0-row input)
# ---------------------------------------------------------------------------


def test_runtime_report_well_formed_before_any_run():
    plan = _pool_plan(_db())
    rep = plan.runtime_report()
    assert rep["ran"] is False and rep["n_dispatches"] == 0
    assert rep["ops"] == [] and rep["queue_wait_ms"] == 0.0
    text = render_analyze(rep)   # must not raise, must say why it is empty
    assert "no chunks dispatched" in text


def test_runtime_report_well_formed_on_empty_table():
    db = Database().add(Multiset.from_columns(
        "t", k=np.array([], np.int32), v=np.array([], np.int32)))
    plan = _pool_plan(db)
    out = plan.run()
    assert out["R"] == []
    rep = plan.runtime_report()   # 0-row input: no dispatches, no crash
    assert rep["ran"] is False or rep["n_dispatches"] >= 0
    render_analyze(rep)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_metrics_snapshot_stable_across_identical_warm_queries():
    s = _session()
    s.sql(Q)                               # cold: compile + cache fill
    snaps = []
    for _ in range(3):
        s.sql(Q)
        snaps.append(s.metrics())
    d1 = diff_counters(snaps[0], snaps[1])
    d2 = diff_counters(snaps[1], snaps[2])
    # measured-time counters (busy/queue ms) legitimately vary run to run;
    # every discrete counter must advance identically on the warm path
    stable = lambda d: {k: v for k, v in d.items() if not k.endswith("ms")}  # noqa: E731
    assert stable(d1) == stable(d2), f"warm deltas drifted: {d1} vs {d2}"
    assert d1["queries{source=sql}"] == 1
    assert d1["plan_cache.hit"] == 1
    assert d1.get("jit.compiles", 0) == 0   # warm: no fresh XLA compiles
    assert d1["rows.scanned"] == 20_000


def test_metrics_match_plan_and_cache_counters():
    s = _session()
    res = s.sql(Q)
    s.sql(Q)
    m = s.metrics()
    c, g = m["counters"], m["gauges"]
    js = res.plan.jit_stats
    assert c["jit.compiles"] == js.compiles
    assert c["jit.hits"] == js.hits
    st = s.plan_cache.stats()
    assert g["plan_cache.hits"] == st["hits"]
    assert g["plan_cache.misses"] == st["misses"]
    assert c["chunks.dispatched"] == 2 * len(res.plan.dispatch_log)
    assert "query.latency_ms" in m["histograms"]


def test_metrics_registry_rejects_negative_and_shares():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.inc("x", -1)
    s1 = _session(metrics=reg)
    s2 = _session(metrics=reg)
    s1.sql(Q)
    s2.sql(Q)
    assert reg.counter_total("queries") == 2   # both sessions feed one registry


def test_table_replacement_counts_invalidations():
    s = _session()
    s.sql(Q)
    s.register("t", **_cols(seed=3))   # replace → old epoch's plans invalid
    assert s.metrics()["counters"]["plan_cache.invalidations"] >= 1


# ---------------------------------------------------------------------------
# Chrome-trace schema + export round-trips
# ---------------------------------------------------------------------------


def test_chrome_trace_schema(tmp_path):
    s = _session()
    with s.profile() as qt:
        s.sql(Q)
    obj = qt.to_chrome()
    # strict JSON (Perfetto rejects Infinity/NaN literals)
    text = json.dumps(obj, allow_nan=False)
    obj = json.loads(text)
    events = obj["traceEvents"]
    assert isinstance(events, list) and events
    assert obj["displayTimeUnit"] in ("ms", "ns")
    xs = [e for e in events if e["ph"] == "X"]
    ms = [e for e in events if e["ph"] == "M"]
    assert len(xs) == len(qt)
    for e in xs:
        assert isinstance(e["name"], str) and e["name"]
        assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0
        assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        assert "span_id" in e["args"] and "cat" in e
    # every tid used by an X event has a thread_name metadata record
    named = {e["tid"] for e in ms if e["name"] == "thread_name"}
    assert {e["tid"] for e in xs} <= named
    assert min(e["ts"] for e in xs) == 0   # rebased to trace start


@pytest.mark.parametrize("fname", ["t.json", "t.json.gz", "t.jsonl", "t.jsonl.gz"])
def test_save_load_round_trip(tmp_path, fname):
    s = _session()
    with s.profile() as qt:
        s.sql(Q)
    path = str(tmp_path / fname)
    qt.save(path)
    back = load_trace(path)
    assert len(back) == len(qt)
    assert sorted(sp.name for sp in back.spans) == sorted(sp.name for sp in qt.spans)
    # the tree survives both formats (ids ride in args for Chrome JSON)
    orig = {sp.id: sp.parent for sp in qt.spans}
    assert {sp.id: sp.parent for sp in back.spans} == orig
    assert len(back.dispatch_records()) == len(qt.dispatch_records())


def test_trace_summary_cli(tmp_path):
    s = _session()
    with s.profile() as qt:
        s.sql(Q)
    path = str(tmp_path / "trace.json.gz")
    qt.save(path)
    spec = importlib.util.spec_from_file_location(
        "trace_summary",
        os.path.join(os.path.dirname(__file__), "..", "scripts", "trace_summary.py"),
    )
    mod = importlib.util.module_from_spec(spec)
    sys.modules["trace_summary"] = mod
    spec.loader.exec_module(mod)
    trace = load_trace(path)
    text = mod.render_summary(trace)
    assert "dispatch" in text and "query" in text and "stage" in text
    assert "chunks=" in mod.render_dispatch(trace)
    assert mod.main([path, "--dispatch"]) == 0


# ---------------------------------------------------------------------------
# bounded query log
# ---------------------------------------------------------------------------


def test_query_log_ring_buffer_and_last_query():
    s = _session(max_query_log=3)
    assert s.last_query() is None
    # distinct query *texts* (same logical query: trailing spaces) so log
    # entries are tellable apart without five cold compiles
    queries = [Q + " " * n for n in (1, 2, 3, 4, 5)]
    for q in queries:
        s.sql(q)
    log = s.query_log
    assert len(log) == 3 and s.max_query_log == 3
    assert [e.query for e in log] == queries[-3:]   # oldest evicted, order kept
    last = s.last_query()
    assert last is log[-1] and last.query == queries[-1]
    assert last.source == "sql" and last.elapsed_s >= 0.0


def test_query_log_cap_validation():
    with pytest.raises(EngineError):
        _session(max_query_log=0)

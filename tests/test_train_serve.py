# Training substrate: optimizer correctness, gradient compression with error
# feedback, checkpoint save/restore (sync + async + resharding), KV-cache
# quantization and generation.
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced_config
from repro.models.transformer import Model
from repro.train.checkpoint import CheckpointManager
from repro.train.grad_compress import compress_leaf, compression_ratio, dequantize_int8, quantize_int8
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, lr_schedule


def test_adamw_matches_reference_adam():
    """One update on a single tensor vs a hand-rolled AdamW."""
    cfg = AdamWConfig(lr_peak=1e-2, warmup_steps=0, total_steps=10, weight_decay=0.1)
    w = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.bfloat16)}
    g = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.4]], jnp.float32)}
    state = adamw_init(w)
    new_w, new_state, _ = adamw_update(cfg, g, state, w)
    # reference
    lr = float(lr_schedule(cfg, jnp.asarray(1)))
    m = 0.1 * np.asarray(g["w"])
    v = 0.05 * np.asarray(g["w"]) ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.95)
    ref = np.asarray(w["w"], np.float32) - lr * (mh / (np.sqrt(vh) + 1e-8) + 0.1 * np.asarray(w["w"], np.float32))
    np.testing.assert_allclose(np.asarray(new_state.master["w"]), ref, rtol=1e-5, atol=1e-5)
    assert new_w["w"].dtype == jnp.bfloat16


def test_grad_clip_scales_global_norm():
    cfg = AdamWConfig(grad_clip=1.0)
    w = {"a": jnp.ones((4,), jnp.bfloat16)}
    g = {"a": jnp.full((4,), 100.0, jnp.float32)}
    _, _, metrics = adamw_update(cfg, g, adamw_init(w), w)
    assert float(metrics["grad_norm"]) > 100.0  # reported pre-clip


def test_int8_quantization_roundtrip(rng):
    x = jnp.asarray(rng.normal(size=(1000,)) * 5, jnp.float32)
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s, x.shape, jnp.float32)
    err = float(jnp.max(jnp.abs(deq - x)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_error_feedback_accumulates_residual(rng):
    """With error feedback, the *sum* of dequantized transmissions converges
    to the sum of true gradients (no systematic bias)."""
    g = jnp.asarray(rng.normal(size=(512,)) * 1e-3, jnp.float32)  # tiny grads
    residual = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    for _ in range(50):
        q, s, residual = compress_leaf(g, residual)
        sent = sent + dequantize_int8(q, s, g.shape, jnp.float32)
    total_err = float(jnp.mean(jnp.abs(sent + residual - 50 * g)))
    assert total_err < 1e-5
    assert compression_ratio({"g": g}) < 0.27


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(10, dtype=jnp.float32), "b": {"c": jnp.ones((3, 3), jnp.bfloat16)},
            "l": [jnp.zeros(2), jnp.ones(2)]}
    mgr.save(10, tree)
    mgr.save(20, tree)
    mgr.save(30, tree)  # keep=2 -> step 10 garbage-collected
    assert mgr.list_steps() == [20, 30]
    step, restored = mgr.restore(tree)
    assert step == 30
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_restore_specific(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.full((8,), 7.0)}
    mgr.save(5, tree, blocking=False)
    mgr.wait()
    step, restored = mgr.restore(tree, step=5)
    assert step == 5 and float(restored["w"][0]) == 7.0


def test_kv_cache_quantization(rng):
    from repro.serve.kvcache import cache_bytes, dequantize_kv, quantize_kv

    cache = {"groups": {"pos0": {"k": jnp.asarray(rng.normal(size=(2, 4, 16, 3, 8)), jnp.bfloat16),
                                 "v": jnp.asarray(rng.normal(size=(2, 4, 16, 3, 8)), jnp.bfloat16)}}}
    q = quantize_kv(cache)
    deq = dequantize_kv(q)
    k0 = np.asarray(cache["groups"]["pos0"]["k"], np.float32)
    k1 = np.asarray(deq["groups"]["pos0"]["k"], np.float32)
    assert np.max(np.abs(k0 - k1)) < np.max(np.abs(k0)) / 32
    assert cache_bytes(q) < 0.8 * cache_bytes(cache)


def test_generate_runs_and_is_deterministic():
    from repro.serve.step import generate

    cfg = dataclasses.replace(reduced_config(get_config("starcoder2-3b")), n_layers=2)
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    prompts = jnp.asarray(np.random.default_rng(0).integers(4, cfg.vocab_size, (2, 8)), jnp.int32)
    r1 = generate(m, params, prompts, max_new_tokens=6)
    r2 = generate(m, params, prompts, max_new_tokens=6)
    np.testing.assert_array_equal(np.asarray(r1.tokens), np.asarray(r2.tokens))
    assert r1.tokens.shape == (2, 8 + 6)

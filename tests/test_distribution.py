# Data-distribution selection (paper §III-A4): conflict detection,
# reorder+fusion resolution (incl. the congruence-witnessed case), and the
# generic chain sharding solver.
import numpy as np
import pytest

from repro.core import transforms as T
from repro.core.distribution import (
    Stage,
    ShardingOption,
    optimize_distribution,
    partition_conflicts,
    solve_chain,
    verify_congruence,
)
from repro.core.ir import (
    Accumulate,
    ArrayRead,
    Const,
    Distinct,
    FieldRef,
    Forelem,
    FullSet,
    Program,
    ResultAppend,
    TupleExpr,
)
from repro.core.lower import CodegenChoices, Plan, ReferenceInterpreter
from repro.data.multiset import Database, Multiset


def two_agg_program():
    def count_prog(field, arr, res):
        return (
            Forelem("i", FullSet("Table"), (Accumulate(arr, FieldRef("Table", "i", field), Const(1)),)),
            Forelem("i", Distinct("Table", field), (
                ResultAppend(res, TupleExpr((FieldRef("Table", "i", field),
                                             ArrayRead(arr, FieldRef("Table", "i", field))))),)),
        )

    return Program(tables=(), body=count_prog("field1", "c1", "R1") + count_prog("field2", "c2", "R2"),
                   results=("R1", "R2"), name="two_agg")


@pytest.fixture
def congruent_db(rng):
    v = rng.integers(0, 12, 400).astype(np.int32)
    return Database().add(Multiset.from_columns("Table", field1=v, field2=rng.permutation(v)))


def _parallel_conflicting(prog):
    p = T.orthogonalize(prog, "Table", "field1", 4, which=[0])
    p = T.orthogonalize(p, "Table", "field2", 4, partvar="k2", valvar="l2", which=[0])
    return T.iteration_space_expansion(p)


def test_paper_two_aggregate_example(congruent_db):
    """§III-A4: conflicting partitionings resolved by reorder + Loop Fusion
    when the value multisets are congruent — no redistribution needed."""
    prog = two_agg_program()
    ref = ReferenceInterpreter(congruent_db).run(prog)
    p = _parallel_conflicting(prog)
    assert len(partition_conflicts(p)) == 1

    p2, report = optimize_distribution(p, db=congruent_db)
    assert report.conflicts_before == 1
    assert report.conflicts_after == 0
    assert report.fusions_applied >= 1

    out = ReferenceInterpreter(congruent_db).run(p2)
    assert sorted(out["R1"]) == sorted(ref["R1"])
    assert sorted(out["R2"]) == sorted(ref["R2"])
    got = Plan(p2, congruent_db, CodegenChoices(parallel="vmap")).run()
    assert sorted(got["R1"]) == sorted(ref["R1"])
    assert sorted(got["R2"]) == sorted(ref["R2"])


def test_non_congruent_fields_not_fused(rng):
    """Different value multisets: fusion must NOT be applied blindly; results
    stay correct either way."""
    a = rng.integers(0, 12, 300).astype(np.int32)
    b = rng.integers(5, 30, 300).astype(np.int32)  # different value range
    db = Database().add(Multiset.from_columns("Table", field1=a, field2=b))
    assert not verify_congruence(db, "Table", "field1", "Table", "field2")
    prog = two_agg_program()
    ref = ReferenceInterpreter(db).run(prog)
    p = _parallel_conflicting(prog)
    p2, report = optimize_distribution(p, db=db)
    out = ReferenceInterpreter(db).run(p2)
    assert sorted(out["R1"]) == sorted(ref["R1"])
    assert sorted(out["R2"]) == sorted(ref["R2"])


def test_chain_solver_prefers_consistent_sharding():
    """The Viterbi solver keeps one layout when resharding dominates, and
    switches when a stage's internal cost dominates."""
    A = ShardingOption("batch", (("x", "data"),), internal_cost=1.0)
    B = ShardingOption("model", (("x", "model"),), internal_cost=1.0)
    big = 8e9  # boundary bytes
    stages = [Stage("s1", [A, B], 0.0), Stage("s2", [A, B], big), Stage("s3", [A, B], big)]
    opts, cost = solve_chain(stages, link_bw=50e9)
    assert len({o.name for o in opts}) == 1  # no resharding

    # layout B free inside stage 2/3 but the boundary is huge: resharding
    # (2 × 16 s) costs more than the internal saving (2 s) — stay consistent
    B2 = ShardingOption("model", (("x", "model"),), internal_cost=0.0)
    huge = 8e11
    stages2 = [Stage("s1", [A], 0.0), Stage("s2", [A, B2], huge), Stage("s3", [A, B2], huge)]
    opts2, _ = solve_chain(stages2, link_bw=50e9)
    assert [o.name for o in opts2] == ["batch", "batch", "batch"]

    # tiny boundary: switching pays off
    stages3 = [Stage("s1", [A], 0.0), Stage("s2", [A, B2], 1.0), Stage("s3", [A, B2], 1.0)]
    opts3, _ = solve_chain(stages3, link_bw=50e9)
    assert [o.name for o in opts3] == ["batch", "model", "model"]

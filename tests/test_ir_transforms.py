# Transform semantics: every re-targeted compiler transformation must
# preserve program results (checked against the reference interpreter), and
# the vectorized JAX lowering must agree with the reference on every
# supported pattern.  Property-based (hypothesis) over random programs/data.
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    Accumulate,
    ArrayRead,
    BinOp,
    CodegenChoices,
    Const,
    Distinct,
    FieldMatch,
    FieldRef,
    Filtered,
    Forelem,
    FullSet,
    Plan,
    Program,
    ResultAppend,
    ScalarAssign,
    TupleExpr,
    Var,
)
from repro.core import transforms as T
from repro.core.lower import ReferenceInterpreter
from repro.core.partition import partition_direct
from repro.data.multiset import Database, Multiset


def groupby_program(op="+", value_field=None, results=("R",)):
    val = Const(1) if value_field is None else FieldRef("T", "i", value_field)
    return Program(
        tables=(),
        body=(
            Forelem("i", FullSet("T"), (Accumulate("acc", FieldRef("T", "i", "k"), val, op),)),
            Forelem(
                "i",
                Distinct("T", "k"),
                (ResultAppend("R", TupleExpr((FieldRef("T", "i", "k"), ArrayRead("acc", FieldRef("T", "i", "k"))))),),
            ),
        ),
        results=results,
        name="gb",
    )


def make_db(rng, n=200, nk=13):
    return Database().add(
        Multiset.from_columns(
            "T",
            k=rng.integers(0, nk, n).astype(np.int32),
            v=rng.integers(0, 50, n).astype(np.int32),
        )
    )


def run_ref(p, db, params=None):
    out = ReferenceInterpreter(db, params).run(p)
    return {k: sorted(v) if isinstance(v, list) else v for k, v in out.items()}


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(10, 400),
    nk=st.integers(1, 40),
    nparts=st.integers(1, 7),
    seed=st.integers(0, 1000),
    value_field=st.sampled_from([None, "v"]),
)
def test_property_groupby_parallelization_preserves_semantics(n, nk, nparts, seed, value_field):
    """Direct/indirect partitioning + ISE + fusion never change results."""
    rng = np.random.default_rng(seed)
    db = make_db(rng, n, nk)
    p = groupby_program(value_field=value_field)
    expected = run_ref(p, db)

    p_ind = T.parallelize_groupby(p, "T", "k", nparts)
    assert run_ref(p_ind, db) == expected

    p_dir = T.iteration_space_expansion(partition_direct(p, nparts))
    assert run_ref(p_dir, db) == expected


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(10, 300),
    nk=st.integers(1, 30),
    seed=st.integers(0, 1000),
    method=st.sampled_from(["dense", "onehot", "sort"]),
    parallel=st.sampled_from(["none", "vmap"]),
)
def test_property_jax_lowering_matches_reference(n, nk, seed, method, parallel):
    rng = np.random.default_rng(seed)
    db = make_db(rng, n, nk)
    p = groupby_program(value_field="v")
    if parallel == "vmap":
        p = T.parallelize_groupby(p, "T", "k", 4)
    expected = run_ref(p, db)
    got = Plan(p, db, CodegenChoices(agg_method=method, parallel=parallel)).run()
    assert sorted(got["R"]) == expected["R"]


def test_dce_removes_dead_aggregate():
    p = groupby_program()
    # add a second aggregate whose array is never read
    dead = Forelem("i", FullSet("T"), (Accumulate("dead", FieldRef("T", "i", "k"), Const(1)),))
    p2 = p.with_body((dead,) + p.body)
    p3 = T.dead_code_elimination(p2)
    from repro.core.ir import walk
    accs = [s.array for s in walk(p3.body) if isinstance(s, Accumulate)]
    assert "dead" not in accs
    rng = np.random.default_rng(0)
    db = make_db(rng)
    assert run_ref(p3, db) == run_ref(p, db)


def test_loop_fusion_fuses_identical_scans():
    p = Program(
        tables=(),
        body=(
            Forelem("i", FullSet("T"), (Accumulate("a", FieldRef("T", "i", "k"), Const(1)),)),
            Forelem("j", FullSet("T"), (Accumulate("b", FieldRef("T", "j", "k"), FieldRef("T", "j", "v")),)),
            Forelem("i", Distinct("T", "k"), (ResultAppend("R", TupleExpr((
                FieldRef("T", "i", "k"),
                ArrayRead("a", FieldRef("T", "i", "k")),
                ArrayRead("b", FieldRef("T", "i", "k"))))),)),
        ),
        results=("R",),
    )
    fused = T.loop_fusion(p)
    # two scan loops merged into one
    n_scans = sum(1 for s in fused.body if isinstance(s, Forelem) and isinstance(s.indexset, FullSet))
    assert n_scans == 1
    rng = np.random.default_rng(1)
    db = make_db(rng)
    assert run_ref(fused, db) == run_ref(p, db)


def test_loop_interchange_pushes_selective_inner_loop_out():
    inner = Forelem("j", FieldMatch("T", "k", Const(3)), (ScalarAssign("s", FieldRef("T", "j", "v"), "+"),))
    outer = Forelem("i", FullSet("U"), (inner,))
    p = Program(tables=(), body=(outer,), results=("s",))
    p2 = T.loop_interchange(p)
    assert isinstance(p2.body[0], Forelem) and isinstance(p2.body[0].indexset, FieldMatch)
    rng = np.random.default_rng(2)
    db = make_db(rng).add(Multiset.from_columns("U", x=np.arange(5, dtype=np.int32)))
    assert run_ref(p2, db)["s"] == run_ref(p, db)["s"]


def test_scalar_reduce_with_params_and_filter():
    p = Program(
        tables=(),
        body=(
            Forelem(
                "i",
                FieldMatch("T", "k", Var("key")),
                (ScalarAssign("s", BinOp("*", FieldRef("T", "i", "v"), Const(2)), "+"),),
            ),
        ),
        results=("s",),
        params=("key",),
    )
    rng = np.random.default_rng(3)
    db = make_db(rng)
    ref = run_ref(p, db, {"key": 5})
    got = Plan(p, db).run(params={"key": 5})
    assert abs(ref["s"] - got["s"]) < 1e-4


def test_join_matches_reference():
    rng = np.random.default_rng(4)
    A = Multiset.from_columns("A", fk=rng.integers(0, 30, 100).astype(np.int32),
                              x=rng.integers(0, 9, 100).astype(np.int32))
    B = Multiset.from_columns("B", id=np.arange(30).astype(np.int32),
                              y=rng.integers(0, 9, 30).astype(np.int32))
    db = Database().add(A).add(B)
    p = Program(
        tables=(),
        body=(
            Forelem("i", FullSet("A"), (
                Forelem("j", FieldMatch("B", "id", FieldRef("A", "i", "fk")), (
                    ResultAppend("R", TupleExpr((FieldRef("A", "i", "x"), FieldRef("B", "j", "y")))),
                )),
            )),
        ),
        results=("R",),
    )
    assert sorted(Plan(p, db).run()["R"]) == run_ref(p, db)["R"]


def test_filtered_scan_projection():
    pred = BinOp(">", FieldRef("T", "_", "v"), Const(25))
    p = Program(
        tables=(),
        body=(
            Forelem("i", Filtered("T", pred), (ResultAppend("R", TupleExpr((FieldRef("T", "i", "k"),))),)),
        ),
        results=("R",),
    )
    rng = np.random.default_rng(5)
    db = make_db(rng)
    assert sorted(Plan(p, db).run()["R"]) == run_ref(p, db)["R"]
